"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, window=None):
    """q: (b, nh, sq, hd); k/v: (b, nkv, sk, hd)."""
    b, nh, sq, hd = q.shape
    _, nkv, sk, _ = k.shape
    groups = nh // nkv
    qg = q.reshape(b, nkv, groups, sq, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bkgqh,bksh->bkgqs", qg, kf) / math.sqrt(hd)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bksh->bkgqh", probs, vf)
    return out.reshape(b, nh, sq, hd).astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, tables, lengths, *, window=None):
    """Gather-based oracle for single-token paged attention.

    q: (n, nh, hd); k/v_pages: (P, bs, nkv, hd); tables: (n, B) physical
    block ids; lengths: (n,) valid rows per lane including the current
    token.  Gathers each lane's logical sequence contiguous (the copy the
    Pallas kernel exists to avoid), masks rows past ``length`` to -1e30 —
    masked rows contribute exactly zero weight, so stale page contents
    never perturb the output — and runs the same grouped-GQA f32 softmax
    as ``_sdpa_dense``.  Doubles as the scanned pure-jnp fallback path for
    backends/families the kernel doesn't cover."""
    n, nh, hd = q.shape
    _, bs, nkv, _ = k_pages.shape
    n_blocks = tables.shape[1]
    groups = nh // nkv
    k = k_pages[tables].reshape(n, n_blocks * bs, nkv, hd)
    v = v_pages[tables].reshape(n, n_blocks * bs, nkv, hd)
    qg = q.reshape(n, nkv, groups, hd).astype(jnp.float32)
    logits = jnp.einsum("nkgh,nskh->nkgs", qg,
                        k.astype(jnp.float32)) / math.sqrt(hd)
    kv_pos = jnp.arange(n_blocks * bs)[None, :]
    mask = kv_pos < lengths[:, None]
    if window is not None:
        mask &= kv_pos > (lengths[:, None] - 1) - window
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("nkgs,nskh->nkgh", probs, v.astype(jnp.float32))
    return out.reshape(n, nh, hd).astype(q.dtype)


def paged_verify_ref(q, k_pages, v_pages, tables, lengths, *, window=None):
    """Gather-based oracle for multi-query (speculative verify) paged
    attention.

    q: (n, k, nh, hd) — k query positions per lane, position ``i`` sitting
    at logical row ``lengths[lane] + i`` (its K/V row is already written);
    k/v_pages: (P, bs, nkv, hd); tables: (n, B); lengths: (n,) rows
    committed BEFORE this round (so query ``i`` attends to
    ``[0, lengths + i]``).  This is exactly the gathered math
    ``models/layers.paged_attention_verify`` historically ran inline — now
    the oracle (and jnp fallback) for the fused multi-query kernel."""
    n, kk, nh, hd = q.shape
    _, bs, nkv, _ = k_pages.shape
    nb = tables.shape[1]
    groups = nh // nkv
    kg = k_pages[tables].reshape(n, nb * bs, nkv, hd)
    vg = v_pages[tables].reshape(n, nb * bs, nkv, hd)
    qg = q.reshape(n, kk, nkv, groups, hd).astype(jnp.float32)
    logits = jnp.einsum("nqkgh,nskh->nkgqs", qg,
                        kg.astype(jnp.float32)) / math.sqrt(hd)
    positions = lengths[:, None] + jnp.arange(kk)[None, :]        # (n, k)
    kv_pos = jnp.arange(nb * bs)[None, None, :]
    mask = kv_pos <= positions[:, :, None]                        # (n, k, s)
    if window is not None:
        mask &= kv_pos > positions[:, :, None] - window
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("nkgqs,nskh->nqkgh", probs, vg.astype(jnp.float32))
    return out.reshape(n, kk, nh, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# int8 KV quantization (per-row symmetric; scales stored alongside pages)
# ---------------------------------------------------------------------------

QUANT_EPS = 1e-8


def quantize_kv(x):
    """Symmetric per-row int8 quantization over the trailing (head_dim)
    axis: ``scale = max|x| / 127`` (clamped away from zero so all-zero
    rows — fresh pages, the garbage block — round-trip to exact zeros).
    Returns ``(q int8, scale f32)`` with ``scale`` shaped like ``x`` minus
    its last axis."""
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32), axis=-1)
    scale = jnp.maximum(amax, QUANT_EPS) / 127.0
    q = jnp.clip(jnp.round(x32 / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_kv(q, scale):
    """Inverse of ``quantize_kv``: f32 rows from int8 values + scales."""
    return q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)


def paged_attention_quant_ref(q, k_pages, v_pages, k_scales, v_scales,
                              tables, lengths, *, window=None):
    """Gather-based oracle for int8-quantized paged attention.

    k/v_pages: (P, bs, nkv, hd) int8; k/v_scales: (P, bs, nkv) f32 per-row
    scales.  Gathers the int8 blocks + scales through the table,
    dequantizes, and runs the same grouped-GQA f32 softmax as
    ``paged_attention_ref`` — the allclose ground truth for the
    dequantizing Pallas kernel AND the jnp serving fallback."""
    n, nh, hd = q.shape
    _, bs, nkv, _ = k_pages.shape
    nb = tables.shape[1]
    groups = nh // nkv
    k = dequantize_kv(k_pages[tables].reshape(n, nb * bs, nkv, hd),
                      k_scales[tables].reshape(n, nb * bs, nkv))
    v = dequantize_kv(v_pages[tables].reshape(n, nb * bs, nkv, hd),
                      v_scales[tables].reshape(n, nb * bs, nkv))
    qg = q.reshape(n, nkv, groups, hd).astype(jnp.float32)
    logits = jnp.einsum("nkgh,nskh->nkgs", qg, k) / math.sqrt(hd)
    kv_pos = jnp.arange(nb * bs)[None, :]
    mask = kv_pos < lengths[:, None]
    if window is not None:
        mask &= kv_pos > (lengths[:, None] - 1) - window
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("nkgs,nskh->nkgh", probs, v)
    return out.reshape(n, nh, hd).astype(q.dtype)


def paged_verify_quant_ref(q, k_pages, v_pages, k_scales, v_scales,
                           tables, lengths, *, window=None):
    """Multi-query verify over int8 pages: gather + dequantize, then the
    `paged_verify_ref` math.  This IS the int8 verify path (spec decode
    over a quantized inner) — a dedicated multi-query quant kernel is not
    worth its surface at draft depths k<=8."""
    n, kk, nh, hd = q.shape
    _, bs, nkv, _ = k_pages.shape
    nb = tables.shape[1]
    groups = nh // nkv
    kg = dequantize_kv(k_pages[tables].reshape(n, nb * bs, nkv, hd),
                       k_scales[tables].reshape(n, nb * bs, nkv))
    vg = dequantize_kv(v_pages[tables].reshape(n, nb * bs, nkv, hd),
                       v_scales[tables].reshape(n, nb * bs, nkv))
    qg = q.reshape(n, kk, nkv, groups, hd).astype(jnp.float32)
    logits = jnp.einsum("nqkgh,nskh->nkgqs", qg, kg) / math.sqrt(hd)
    positions = lengths[:, None] + jnp.arange(kk)[None, :]
    kv_pos = jnp.arange(nb * bs)[None, None, :]
    mask = kv_pos <= positions[:, :, None]
    if window is not None:
        mask &= kv_pos > positions[:, :, None] - window
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("nkgqs,nskh->nqkgh", probs, vg)
    return out.reshape(n, kk, nh, hd).astype(q.dtype)


def fused_decode_layer_ref(h, q, k_pages, v_pages, tables, lengths, wo,
                           mlp_scale, w_gate, w_up, w_down, *,
                           window=None, eps: float = 1e-6):
    """Oracle for the fused paged decode layer: paged attention through
    the block table, output projection + residual add, RMSNorm, SwiGLU,
    second residual — the whole per-layer epilogue after QKV projection /
    rope / the KV scatter (which stay outside: they write the pages).

    h: (n, d) residual stream; q: (n, nh, hd) roped queries;
    lengths: valid rows per lane INCLUDING the current token (the
    ``paged_attention`` convention).  Returns the next (n, d) residual."""
    n, nh, hd = q.shape
    attn = paged_attention_ref(q, k_pages, v_pages, tables, lengths,
                               window=window)
    h32 = h.astype(jnp.float32)
    h1 = h32 + attn.reshape(n, nh * hd).astype(jnp.float32) \
        @ wo.astype(jnp.float32)
    var = jnp.mean(jnp.square(h1), axis=-1, keepdims=True)
    hn = h1 * jax.lax.rsqrt(var + eps) * mlp_scale.astype(jnp.float32)
    g = hn @ w_gate.astype(jnp.float32)
    u = hn @ w_up.astype(jnp.float32)
    out = h1 + (jax.nn.silu(g) * u) @ w_down.astype(jnp.float32)
    return out.astype(h.dtype)


def ssd_scan_ref(x, log_a, b_coef, c_coef, *, chunk: int):
    """Sequential-recurrence oracle (O(s) scan, independent of the chunked
    algorithm): S_t = exp(a_t) S_{t-1} + B_t x_t^T ; y_t = C_t · S_t."""
    bsz, s, h, p = x.shape
    n = b_coef.shape[-1]
    f32 = jnp.float32

    def step(state, inp):
        x_t, a_t, b_t, c_t = inp
        state = state * jnp.exp(a_t.astype(f32))[..., None, None] \
            + x_t.astype(f32)[..., None] * b_t.astype(f32)[..., None, :]
        y = jnp.einsum("bhpn,bhn->bhp", state, c_t.astype(f32))
        return state, y

    init = jnp.zeros((bsz, h, p, n), f32)
    xs = (x.transpose(1, 0, 2, 3), log_a.transpose(1, 0, 2),
          b_coef.transpose(1, 0, 2, 3), c_coef.transpose(1, 0, 2, 3))
    _, ys = jax.lax.scan(step, init, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)


def rms_norm_ref(x, w, *, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)
            * w.astype(jnp.float32)).astype(x.dtype)


def swiglu_ref(x, w_gate, w_up, w_down):
    x32 = x.astype(jnp.float32)
    g = x32 @ w_gate.astype(jnp.float32)
    u = x32 @ w_up.astype(jnp.float32)
    return ((jax.nn.silu(g) * u) @ w_down.astype(jnp.float32)).astype(x.dtype)
