"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, window=None):
    """q: (b, nh, sq, hd); k/v: (b, nkv, sk, hd)."""
    b, nh, sq, hd = q.shape
    _, nkv, sk, _ = k.shape
    groups = nh // nkv
    qg = q.reshape(b, nkv, groups, sq, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bkgqh,bksh->bkgqs", qg, kf) / math.sqrt(hd)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bksh->bkgqh", probs, vf)
    return out.reshape(b, nh, sq, hd).astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, tables, lengths, *, window=None):
    """Gather-based oracle for single-token paged attention.

    q: (n, nh, hd); k/v_pages: (P, bs, nkv, hd); tables: (n, B) physical
    block ids; lengths: (n,) valid rows per lane including the current
    token.  Gathers each lane's logical sequence contiguous (the copy the
    Pallas kernel exists to avoid), masks rows past ``length`` to -1e30 —
    masked rows contribute exactly zero weight, so stale page contents
    never perturb the output — and runs the same grouped-GQA f32 softmax
    as ``_sdpa_dense``.  Doubles as the scanned pure-jnp fallback path for
    backends/families the kernel doesn't cover."""
    n, nh, hd = q.shape
    _, bs, nkv, _ = k_pages.shape
    n_blocks = tables.shape[1]
    groups = nh // nkv
    k = k_pages[tables].reshape(n, n_blocks * bs, nkv, hd)
    v = v_pages[tables].reshape(n, n_blocks * bs, nkv, hd)
    qg = q.reshape(n, nkv, groups, hd).astype(jnp.float32)
    logits = jnp.einsum("nkgh,nskh->nkgs", qg,
                        k.astype(jnp.float32)) / math.sqrt(hd)
    kv_pos = jnp.arange(n_blocks * bs)[None, :]
    mask = kv_pos < lengths[:, None]
    if window is not None:
        mask &= kv_pos > (lengths[:, None] - 1) - window
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("nkgs,nskh->nkgh", probs, v.astype(jnp.float32))
    return out.reshape(n, nh, hd).astype(q.dtype)


def ssd_scan_ref(x, log_a, b_coef, c_coef, *, chunk: int):
    """Sequential-recurrence oracle (O(s) scan, independent of the chunked
    algorithm): S_t = exp(a_t) S_{t-1} + B_t x_t^T ; y_t = C_t · S_t."""
    bsz, s, h, p = x.shape
    n = b_coef.shape[-1]
    f32 = jnp.float32

    def step(state, inp):
        x_t, a_t, b_t, c_t = inp
        state = state * jnp.exp(a_t.astype(f32))[..., None, None] \
            + x_t.astype(f32)[..., None] * b_t.astype(f32)[..., None, :]
        y = jnp.einsum("bhpn,bhn->bhp", state, c_t.astype(f32))
        return state, y

    init = jnp.zeros((bsz, h, p, n), f32)
    xs = (x.transpose(1, 0, 2, 3), log_a.transpose(1, 0, 2),
          b_coef.transpose(1, 0, 2, 3), c_coef.transpose(1, 0, 2, 3))
    _, ys = jax.lax.scan(step, init, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)


def rms_norm_ref(x, w, *, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)
            * w.astype(jnp.float32)).astype(x.dtype)


def swiglu_ref(x, w_gate, w_up, w_down):
    x32 = x.astype(jnp.float32)
    g = x32 @ w_gate.astype(jnp.float32)
    u = x32 @ w_up.astype(jnp.float32)
    return ((jax.nn.silu(g) * u) @ w_down.astype(jnp.float32)).astype(x.dtype)
