"""Pallas TPU fused RMSNorm: one HBM round-trip per row block.

Grid over row blocks; each step normalizes a (block_rows, d) tile in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rms_norm_2d(x, w, *, eps: float = 1e-6, block_rows: int = 256,
                interpret: bool = False):
    """x: (rows, d); w: (d,)."""
    rows, d = x.shape
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    nb = x.shape[0] // block_rows
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(nb,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, w)
    return out[:rows]
