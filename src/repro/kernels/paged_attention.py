"""Pallas TPU paged attention: single-token decode over a block-table KV.

The serving engine stores K/V in fixed-size physical blocks
(``(n_blocks, block_size, n_kv_heads, head_dim)`` pages); each decode lane
owns a *logical* sequence named by a block table row.  The kernel reads K/V
straight through the table — grid ``(lane, kv_head, logical_block)`` with
the block dimension innermost so the running online-softmax scratch
``(m, l, acc)`` carries across it, exactly like the flash kernel — and the
table is a scalar-prefetch operand, so the physical block id feeds the K/V
``BlockSpec`` index maps and no gathered contiguous copy of the cache is
ever materialized (the whole point of paging: the contiguous gather would
cost a ``max_seq``-sized copy per lane per step).

GQA mirrors ``flash_attention.py``: q is blocked ``(1, groups, head_dim)``
per kv head and repeated K/V heads are never materialized.  Logical blocks
past a lane's length are masked to ``NEG_INF`` (their table entries point at
the reserved garbage block 0, a valid physical index), so stale or
unallocated pages contribute exactly zero attention weight.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(tables_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *,
                  scale: float, block_size: int, window):
    lane = pl.program_id(0)
    b = pl.program_id(2)
    nb = pl.num_programs(2)

    @pl.when(b == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)             # (groups, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)       # (block_size, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale

    length = lengths_ref[lane]                   # valid rows incl. this token
    k_pos = b * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (q.shape[0], block_size), 1)
    mask = k_pos < length
    if window is not None:
        mask &= k_pos > (length - 1) - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_cur[:, None])
    alpha = jnp.exp(m_prev - m_cur)
    l_scr[...] = l_prev * alpha + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + p @ v
    m_scr[...] = m_cur

    @pl.when(b == nb - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def paged_attention_lanes(q, k_pages, v_pages, tables, lengths, *,
                          window=None, interpret: bool = False):
    """q: (n, nh, hd); k/v_pages: (P, bs, nkv, hd); tables: (n, B) physical
    block ids (every entry must be a valid index — pad with the garbage
    block); lengths: (n,) valid rows per lane INCLUDING the current token.
    Returns (n, nh, hd) in q's dtype."""
    n, nh, hd = q.shape
    _, block_size, nkv, _ = k_pages.shape
    n_blocks = tables.shape[1]
    assert nh % nkv == 0
    groups = nh // nkv
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(_paged_kernel, scale=scale,
                               block_size=block_size, window=window)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                   # tables, lengths
        grid=(n, nkv, n_blocks),
        in_specs=[
            pl.BlockSpec((1, groups, hd),
                         lambda i, kv, b, t, le: (i, kv, 0)),
            pl.BlockSpec((1, block_size, 1, hd),
                         lambda i, kv, b, t, le: (t[i, b], 0, kv, 0)),
            pl.BlockSpec((1, block_size, 1, hd),
                         lambda i, kv, b, t, le: (t[i, b], 0, kv, 0)),
        ],
        out_specs=pl.BlockSpec((1, groups, hd),
                               lambda i, kv, b, t, le: (i, kv, 0)),
        scratch_shapes=[
            pltpu.VMEM((groups,), jnp.float32),      # running max m
            pltpu.VMEM((groups,), jnp.float32),      # running denom l
            pltpu.VMEM((groups, hd), jnp.float32),   # output accumulator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, nh, hd), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), lengths.astype(jnp.int32), q,
      k_pages, v_pages)


def _paged_quant_kernel(tables_ref, lengths_ref, q_ref, k_ref, v_ref,
                        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr, *,
                        scale: float, block_size: int, window):
    lane = pl.program_id(0)
    b = pl.program_id(2)
    nb = pl.num_programs(2)

    @pl.when(b == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)             # (groups, hd)
    # int8 rows dequantized in-registers: the cache stays int8 in HBM/VMEM
    # (~3.8x smaller per row at hd=64), only this block ever exists in f32.
    k = k_ref[0, :, 0].astype(jnp.float32) * ks_ref[0, :, 0][:, None]
    v = v_ref[0, :, 0].astype(jnp.float32) * vs_ref[0, :, 0][:, None]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale

    length = lengths_ref[lane]                   # valid rows incl. this token
    k_pos = b * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (q.shape[0], block_size), 1)
    mask = k_pos < length
    if window is not None:
        mask &= k_pos > (length - 1) - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_cur[:, None])
    alpha = jnp.exp(m_prev - m_cur)
    l_scr[...] = l_prev * alpha + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + p @ v
    m_scr[...] = m_cur

    @pl.when(b == nb - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def paged_attention_quant_lanes(q, k_pages, v_pages, k_scales, v_scales,
                                tables, lengths, *,
                                window=None, interpret: bool = False):
    """int8-KV variant of `paged_attention_lanes`: k/v_pages are
    (P, bs, nkv, hd) int8, k/v_scales are (P, bs, nkv) f32 per-row
    symmetric scales (`ref.quantize_kv`).  Scale blocks ride the same
    table-driven BlockSpec index maps as the pages, so dequantization
    happens inside the kernel and no f32 copy of the cache is ever
    materialized.  Returns (n, nh, hd) in q's dtype."""
    n, nh, hd = q.shape
    _, block_size, nkv, _ = k_pages.shape
    n_blocks = tables.shape[1]
    assert nh % nkv == 0
    groups = nh // nkv
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(_paged_quant_kernel, scale=scale,
                               block_size=block_size, window=window)

    page_spec = pl.BlockSpec((1, block_size, 1, hd),
                             lambda i, kv, b, t, le: (t[i, b], 0, kv, 0))
    scale_spec = pl.BlockSpec((1, block_size, 1),
                              lambda i, kv, b, t, le: (t[i, b], 0, kv))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                   # tables, lengths
        grid=(n, nkv, n_blocks),
        in_specs=[
            pl.BlockSpec((1, groups, hd),
                         lambda i, kv, b, t, le: (i, kv, 0)),
            page_spec, page_spec, scale_spec, scale_spec,
        ],
        out_specs=pl.BlockSpec((1, groups, hd),
                               lambda i, kv, b, t, le: (i, kv, 0)),
        scratch_shapes=[
            pltpu.VMEM((groups,), jnp.float32),      # running max m
            pltpu.VMEM((groups,), jnp.float32),      # running denom l
            pltpu.VMEM((groups, hd), jnp.float32),   # output accumulator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, nh, hd), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), lengths.astype(jnp.int32), q,
      k_pages, v_pages, k_scales.astype(jnp.float32),
      v_scales.astype(jnp.float32))
