"""Pallas TPU fused SwiGLU MLP: silu(x·Wg) ⊙ (x·Wu) · Wd without
materializing the (tokens, d_ff) hidden in HBM.

Grid ``(m_block, f_block)`` with the d_ff-block dimension innermost; the
(block_m, d) output accumulator carries across f blocks in VMEM scratch, so
the hidden activation only ever exists one (block_m, block_f) tile at a time.
With block_m=256, block_f=512, d=4096: tiles ≈ 0.5–4 MB f32, inside VMEM;
contractions are 128-aligned for the MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _swiglu_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_scr):
    fi = pl.program_id(1)
    nf = pl.num_programs(1)

    @pl.when(fi == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...].astype(jnp.float32)           # (bm, d)
    wg = wg_ref[...].astype(jnp.float32)         # (d, bf)
    wu = wu_ref[...].astype(jnp.float32)
    wd = wd_ref[...].astype(jnp.float32)         # (bf, d)

    g = x @ wg
    u = x @ wu
    h = jax.nn.silu(g) * u                       # (bm, bf) — VMEM only
    acc_scr[...] += h @ wd

    @pl.when(fi == nf - 1)
    def _finish():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


def swiglu_2d(x, w_gate, w_up, w_down, *, block_m: int = 256,
              block_f: int = 512, interpret: bool = False):
    """x: (m, d); w_gate/w_up: (d, f); w_down: (f, d)."""
    m, d = x.shape
    f = w_gate.shape[1]
    block_m = min(block_m, m)
    block_f = min(block_f, f)
    pad_m = (-m) % block_m
    pad_f = (-f) % block_f
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
    if pad_f:
        w_gate = jnp.pad(w_gate, ((0, 0), (0, pad_f)))
        w_up = jnp.pad(w_up, ((0, 0), (0, pad_f)))
        w_down = jnp.pad(w_down, ((0, pad_f), (0, 0)))
    nm = x.shape[0] // block_m
    nf = w_gate.shape[1] // block_f

    out = pl.pallas_call(
        _swiglu_kernel,
        grid=(nm, nf),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda mi, fi: (mi, 0)),
            pl.BlockSpec((d, block_f), lambda mi, fi: (0, fi)),
            pl.BlockSpec((d, block_f), lambda mi, fi: (0, fi)),
            pl.BlockSpec((block_f, d), lambda mi, fi: (fi, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, d), lambda mi, fi: (mi, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], d), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, d), jnp.float32)],
        interpret=interpret,
    )(x, w_gate, w_up, w_down)
    return out[:m]
