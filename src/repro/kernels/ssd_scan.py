"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Grid ``(batch, head, chunk)`` with the chunk dimension innermost; the
``(p, n)`` inter-chunk state lives in VMEM scratch and carries across chunk
steps — the hardware-native expression of "quadratic within a chunk, linear
recurrence across chunks".  Per-step VMEM working set with Q=256, p=64,
n=128: x (Q,p) + B,C (Q,n) + decay (Q,Q) + state (p,n) ≈ 0.5 MB f32.
All contraction dims (Q, p, n) are MXU-tile friendly.

Also serves mLSTM (matrix-memory) since its recurrence is the same SSD form
with per-head scalar decay — see repro/models/ssm.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, o_ref, state_scr, *,
                chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)             # (Q, p)
    a = a_ref[0].astype(jnp.float32)             # (Q,)
    b = b_ref[0].astype(jnp.float32)             # (Q, n)
    c = c_ref[0].astype(jnp.float32)             # (Q, n)

    a_cum = jnp.cumsum(a)                        # (Q,)
    a_tot = a_cum[-1]

    # intra-chunk (quadratic in Q)
    li = a_cum[:, None] - a_cum[None, :]
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    li = jnp.where(row >= col, li, -1e30)        # mask BEFORE exp
    decay = jnp.exp(li)
    scores = (c @ b.T) * decay                   # (Q, Q)
    y = scores @ x                               # (Q, p)

    # inter-chunk contribution from the carried state
    state = state_scr[...]                       # (p, n)
    y = y + jnp.exp(a_cum)[:, None] * (c @ state.T)

    # state update for the next chunk
    w = jnp.exp(a_tot - a_cum)                   # (Q,)
    state_scr[...] = jnp.exp(a_tot) * state + (x * w[:, None]).T @ b

    o_ref[0] = y.astype(o_ref.dtype)


def ssd_scan_bshpn(x, log_a, b_coef, c_coef, *, chunk: int,
                   interpret: bool = False):
    """x: (b, s, h, p); log_a: (b, s, h); b/c: (b, s, h, n) -> y like x.

    Reshapes to (b, h, nc, Q, ·) blocks and runs the chunk-sequential grid.
    """
    bsz, s, h, p = x.shape
    n = b_coef.shape[-1]
    assert s % chunk == 0
    nc = s // chunk

    xt = x.transpose(0, 2, 1, 3).reshape(bsz, h, nc, chunk, p)
    at = log_a.transpose(0, 2, 1).reshape(bsz, h, nc, chunk)
    bt = b_coef.transpose(0, 2, 1, 3).reshape(bsz, h, nc, chunk, n)
    ct = c_coef.transpose(0, 2, 1, 3).reshape(bsz, h, nc, chunk, n)
    # fold (b, h) since the grid treats them identically
    xt = xt.reshape(bsz * h, nc, chunk, p)
    at = at.reshape(bsz * h, nc, chunk)
    bt = bt.reshape(bsz * h, nc, chunk, n)
    ct = ct.reshape(bsz * h, nc, chunk, n)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(bsz * h, nc),
        in_specs=[
            pl.BlockSpec((1, None, chunk, p), lambda bh, ci: (bh, ci, 0, 0)),
            pl.BlockSpec((1, None, chunk), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, None, chunk, n), lambda bh, ci: (bh, ci, 0, 0)),
            pl.BlockSpec((1, None, chunk, n), lambda bh, ci: (bh, ci, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, None, chunk, p),
                               lambda bh, ci: (bh, ci, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz * h, nc, chunk, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xt, at, bt, ct)
    return out.reshape(bsz, h, s, p).transpose(0, 2, 1, 3)
