"""Pallas TPU flash attention (GQA + causal + sliding window).

Online-softmax accumulation over KV blocks.  Grid layout
``(batch, kv_head, q_group, q_block, kv_block)`` with the KV-block dimension
innermost so the running (m, l, acc) scratch carries across it — the standard
TPU flash schedule.  GQA never materializes repeated K/V: the q BlockSpec
index map folds ``head = kv_head * group_size + group``.

VMEM working set per step:
    q (block_q, hd) + k,v (block_k, hd) + acc (block_q, hd) + scores
    (block_q, block_k) — with the default 128/128 blocks and hd=128 this is
    ~0.4 MB in f32, comfortably inside a v5e core's ~16 MB VMEM, and all
    matmul dims are multiples of the 128-lane MXU tile.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int,
                  seq_q: int, seq_k: int, causal: bool, window):
    qi = pl.program_id(3)
    ki = pl.program_id(4)
    nk = pl.num_programs(4)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (block_q, hd)
    k = k_ref[0, 0].astype(jnp.float32)          # (block_k, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = (q_pos < seq_q) & (k_pos < seq_k)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_cur[:, None])
    alpha = jnp.exp(m_prev - m_cur)
    l_scr[...] = l_prev * alpha + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + p @ v
    m_scr[...] = m_cur

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window=None,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = False):
    """q: (b, nh, sq, hd); k/v: (b, nkv, sk, hd). Returns (b, nh, sq, hd)."""
    b, nh, sq, hd = q.shape
    _, nkv, sk, _ = k.shape
    assert nh % nkv == 0
    groups = nh // nkv
    scale = 1.0 / math.sqrt(hd)

    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = q.shape[2] // block_q
    nk = k.shape[2] // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        seq_q=sq, seq_k=sk, causal=causal, window=window)

    out = pl.pallas_call(
        kernel,
        grid=(b, nkv, groups, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b_, kv, g, qi, ki: (b_, kv * groups + g, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b_, kv, g, qi, ki: (b_, kv, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b_, kv, g, qi, ki: (b_, kv, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b_, kv, g, qi, ki:
                               (b_, kv * groups + g, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nh, q.shape[2], hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),        # running max m
            pltpu.VMEM((block_q,), jnp.float32),        # running denom l
            pltpu.VMEM((block_q, hd), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :sq]
