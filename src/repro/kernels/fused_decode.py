"""Pallas TPU fused paged decode layer.

One launch per layer covers the whole post-projection decode hot path:
paged attention through the block table (all kv heads of a lane in one
program, so the epilogue has the full attention output), the ``wo``
projection + residual add, the MLP RMSNorm, and the SwiGLU block with
its residual.  QKV projection, rope, and the KV row scatter stay
outside — they write the pages the kernel reads.

Grid is ``(lane, logical_block)`` with the block dimension innermost;
the online-softmax scratch ``(m, l, acc)`` spans all ``n_heads`` rows
and carries across blocks exactly like `paged_attention_lanes`.  At the
last block the epilogue runs once per lane with every weight matrix
resident in VMEM (constant BlockSpec index maps — sized for decode
configs, where d and ffn fit comfortably).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fused_kernel(tables_ref, lengths_ref, h_ref, q_ref, k_ref, v_ref,
                  wo_ref, scale_ref, wg_ref, wu_ref, wd_ref, o_ref,
                  m_scr, l_scr, acc_scr, *,
                  scale: float, block_size: int, n_kv_heads: int,
                  window, eps: float):
    lane = pl.program_id(0)
    b = pl.program_id(1)
    nb = pl.num_programs(1)

    @pl.when(b == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    nh, hd = q_ref.shape[1], q_ref.shape[2]
    nkv = n_kv_heads
    groups = nh // nkv
    q = q_ref[0].astype(jnp.float32).reshape(nkv, groups, hd)
    k = jnp.transpose(k_ref[0].astype(jnp.float32), (1, 0, 2))  # (nkv,bs,hd)
    v = jnp.transpose(v_ref[0].astype(jnp.float32), (1, 0, 2))

    s = jax.lax.dot_general(
        q, k, (((2,), (2,)), ((0,), (0,)))) * scale       # (nkv, groups, bs)
    s = s.reshape(nh, block_size)

    length = lengths_ref[lane]                   # valid rows incl. this token
    k_pos = b * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (nh, block_size), 1)
    mask = k_pos < length
    if window is not None:
        mask &= k_pos > (length - 1) - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_cur[:, None])
    alpha = jnp.exp(m_prev - m_cur)
    l_scr[...] = l_prev * alpha + jnp.sum(p, axis=-1)
    pv = jax.lax.dot_general(
        p.reshape(nkv, groups, block_size), v,
        (((2,), (1,)), ((0,), (0,))))                     # (nkv, groups, hd)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + pv.reshape(nh, hd)
    m_scr[...] = m_cur

    @pl.when(b == nb - 1)
    def _epilogue():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        attn = (acc_scr[...] / denom).reshape(1, nh * hd)
        h1 = h_ref[...].astype(jnp.float32) \
            + attn @ wo_ref[...].astype(jnp.float32)
        var = jnp.mean(jnp.square(h1), axis=-1, keepdims=True)
        hn = h1 * jax.lax.rsqrt(var + eps) \
            * scale_ref[...].astype(jnp.float32)
        g = hn @ wg_ref[...].astype(jnp.float32)
        u = hn @ wu_ref[...].astype(jnp.float32)
        out = h1 + (jax.nn.silu(g) * u) @ wd_ref[...].astype(jnp.float32)
        o_ref[...] = out.astype(o_ref.dtype)


def fused_decode_layer(h, q, k_pages, v_pages, tables, lengths, wo,
                       mlp_scale, w_gate, w_up, w_down, *,
                       window=None, eps: float = 1e-6,
                       interpret: bool = False):
    """h: (n, d) residual stream; q: (n, nh, hd) roped queries whose K/V
    rows are already scattered; k/v_pages: (P, bs, nkv, hd); tables:
    (n, B) physical block ids (pad with the garbage block); lengths: (n,)
    valid rows per lane INCLUDING the current token; wo: (nh*hd, d);
    mlp_scale: (d,); w_gate/w_up: (d, f); w_down: (f, d).  Returns the
    next (n, d) residual in h's dtype."""
    n, nh, hd = q.shape
    _, block_size, nkv, _ = k_pages.shape
    n_blocks = tables.shape[1]
    d = h.shape[1]
    f = w_gate.shape[1]
    assert nh % nkv == 0

    kernel = functools.partial(_fused_kernel, scale=1.0 / math.sqrt(hd),
                               block_size=block_size, n_kv_heads=nkv,
                               window=window, eps=eps)

    page_spec = pl.BlockSpec((1, block_size, nkv, hd),
                             lambda i, b, t, le: (t[i, b], 0, 0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                   # tables, lengths
        grid=(n, n_blocks),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, b, t, le: (i, 0)),
            pl.BlockSpec((1, nh, hd), lambda i, b, t, le: (i, 0, 0)),
            page_spec, page_spec,
            pl.BlockSpec((nh * hd, d), lambda i, b, t, le: (0, 0)),
            pl.BlockSpec((d,), lambda i, b, t, le: (0,)),
            pl.BlockSpec((d, f), lambda i, b, t, le: (0, 0)),
            pl.BlockSpec((d, f), lambda i, b, t, le: (0, 0)),
            pl.BlockSpec((f, d), lambda i, b, t, le: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, b, t, le: (i, 0)),
        scratch_shapes=[
            pltpu.VMEM((nh,), jnp.float32),          # running max m
            pltpu.VMEM((nh,), jnp.float32),          # running denom l
            pltpu.VMEM((nh, hd), jnp.float32),       # attention accumulator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, d), h.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), lengths.astype(jnp.int32), h, q,
      k_pages, v_pages, wo, mlp_scale, w_gate, w_up, w_down)
