"""Kernel micro-benchmarks: XLA reference path wall-times on CPU (the
Pallas kernels themselves target TPU; interpret-mode timing is not a perf
signal, so what we measure here is the oracle path the dry-run lowers).

Besides the CSV rows, ``run()`` writes ``results/bench_kernels.json`` —
per-kernel throughput (rows/s) and the Pallas-vs-reference fallback delta
measured by ``repro.profiler.probes.probe_kernels`` — so dashboards and
the profiler share one measurement path."""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.kernels import ref
from repro.models.ssm import ssd_chunked


def _time(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(json_out: str = "results/bench_kernels.json"):
    key = jax.random.PRNGKey(0)
    # attention oracle
    b, s, nh, nkv, hd = 1, 512, 8, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, nh, s, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, nkv, s, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, nkv, s, hd), jnp.float32)
    fn = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v, causal=True))
    us = _time(fn, q, k, v)
    flops = 4 * b * nh * s * s * hd / 2   # causal half
    emit("kernel_attention_ref_512", us, f"gflops={flops / us / 1e3:.2f}")

    # SSD chunked scan
    b, s, h, p, n = 2, 1024, 4, 64, 64
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    la = -jnp.abs(jax.random.normal(ks[1], (b, s, h))) * 0.1
    bc = jax.random.normal(ks[2], (b, s, h, n)) * 0.3
    cc = jax.random.normal(ks[3], (b, s, h, n)) * 0.3
    fn = jax.jit(lambda *a: ssd_chunked(*a, 128)[0])
    us = _time(fn, x, la, bc, cc)
    emit("kernel_ssd_chunked_1024", us, f"chunk=128")

    # fused swiglu oracle
    m, d, f = 1024, 512, 2048
    ks = jax.random.split(key, 4)
    xm = jax.random.normal(ks[0], (m, d))
    wg = jax.random.normal(ks[1], (d, f)) * 0.05
    wu = jax.random.normal(ks[2], (d, f)) * 0.05
    wd = jax.random.normal(ks[3], (f, d)) * 0.05
    fn = jax.jit(ref.swiglu_ref)
    us = _time(fn, xm, wg, wu, wd)
    emit("kernel_swiglu_ref", us, f"gflops={6 * m * d * f / us / 1e3:.2f}")

    # machine-readable pass: Pallas-vs-reference via the profiler's probes
    # (same numbers a MachineFacts profile would carry)
    from repro.profiler.probes import probe_kernels
    kernels = probe_kernels(quick=True)
    for name, row in sorted(kernels.items()):
        if not isinstance(row, dict) or "fallback_delta" not in row:
            continue
        emit(f"kernel_{name}_pallas", row["kernel_us"],
             f"fallback_delta={row['fallback_delta']:.3f}")
    os.makedirs(os.path.dirname(json_out) or ".", exist_ok=True)
    with open(json_out, "w") as f:
        json.dump({"kernels": kernels}, f, indent=1, sort_keys=True)
    print(f"# kernel json -> {json_out}")
