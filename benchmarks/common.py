"""Shared helpers for the benchmark suite.

All multi-model benchmarks run *real* JAX training at smoke scale through
the Hydra executor; device parallelism is virtualized (measured per-unit
compute + modeled transfers on per-device clocks — see repro/core/sharp.py).
Rows print as ``name,us_per_call,derived`` CSV.
"""

from __future__ import annotations

import sys
import time

import jax

from repro.configs import get_config
from repro.core import HydraConfig, ModelOrchestrator, ModelTask
from repro.core import baselines as bl
from repro.models import api


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
    sys.stdout.flush()


def make_loader(cfg, batch=2, seq=64, seed=0):
    class L:
        def __iter__(self):
            def gen():
                i = 0
                while True:
                    k = jax.random.fold_in(jax.random.PRNGKey(seed), i)
                    yield api.make_dummy_batch(cfg, batch, seq, key=k)
                    i += 1
            return gen()

    return L()


def bert_grid_tasks(n_models=12, steps=2, seq=64, arch="bert-large-1b"):
    """The paper's Table-2 style hyper-parameter grid at smoke scale:
    batch {8,16,32} x lr {1e-3..1e-6} = 12 configs (we keep the *shape* of
    the grid; batch is fixed smoke-small so runtimes stay CPU-feasible)."""
    cfg = get_config(arch, smoke=True)
    lrs = [1e-3, 1e-4, 1e-5, 1e-6]
    tasks = []
    for i in range(n_models):
        tasks.append(ModelTask(cfg, make_loader(cfg, seed=i, seq=seq),
                               lr=lrs[i % len(lrs)], epochs=1,
                               steps_per_epoch=steps, seed=i,
                               batch=2, seq=seq))
    return tasks


def run_hydra(tasks, n_devices=8, budget=6 * 10**6, link_bw=2e9,
              sharp=True, db=True, scheduler="lrtf"):
    hc = HydraConfig(n_devices=n_devices, device_budget_bytes=budget,
                     link_bw=link_bw, enable_sharp=sharp,
                     enable_double_buffer=db, scheduler=scheduler)
    orch = ModelOrchestrator(tasks, hc)
    report = orch.train_models()
    return orch, report


def baseline_reports(orch, tasks, n_devices, budget):
    steps = [t.epochs * t.steps_per_epoch for t in tasks]
    out = {"model_parallel": bl.model_parallel(orch.models, n_devices, steps),
           "pipeline": bl.pipeline(orch.models, n_devices, steps)}
    try:
        out["task_parallel"] = bl.task_parallel(orch.models, n_devices,
                                                steps, budget)
    except MemoryError as e:
        out["task_parallel"] = None
    return out
