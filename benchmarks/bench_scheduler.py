"""Paper Fig 7: Sharded-LRTF vs randomized vs exact optimal (B&B stand-in
for the paper's Gurobi MILP), homogeneous and heterogeneous model sets.

Pure discrete-event simulation over synthetic unit runtimes (the paper's own
methodology for this figure); makespans normalized to the optimal."""

from __future__ import annotations

import random
import time

from benchmarks.common import emit
from repro.core import scheduler as sched


def _simulate(times, n_devices):
    t0 = time.perf_counter()
    lrtf = sched.greedy_list_makespan(times, n_devices, sched.sharded_lrtf)
    lrtf_us = (time.perf_counter() - t0) * 1e6
    rnd = min(sched.greedy_list_makespan(
        times, n_devices, sched.make_random_scheduler(s)) for s in range(3))
    opt = sched.optimal_makespan(times, n_devices, node_limit=120_000)
    return lrtf, rnd, opt, lrtf_us


def run():
    rng = random.Random(0)
    # homogeneous: identical models (paper: 2h epochs, 2000 units — scaled)
    for n_models, n_dev in [(4, 2), (6, 3), (8, 4)]:
        times = [[1.0] * 20 for _ in range(n_models)]
        lrtf, rnd, opt, us = _simulate(times, n_dev)
        emit(f"fig7_hom_m{n_models}_d{n_dev}_lrtf", us,
             f"makespan_vs_opt={lrtf / opt:.3f}")
        emit(f"fig7_hom_m{n_models}_d{n_dev}_random", us,
             f"makespan_vs_opt={rnd / opt:.3f}")
    # heterogeneous: runtimes 1:8 spread, unit counts 5..40 (paper: 30min-4h,
    # 100-10k units — same ratios, scaled for the exact solver)
    for trial in range(3):
        times = [[rng.uniform(0.25, 2.0)] * rng.randint(5, 40)
                 for _ in range(6)]
        lrtf, rnd, opt, us = _simulate(times, 3)
        emit(f"fig7_het_t{trial}_lrtf", us,
             f"makespan_vs_opt={lrtf / opt:.3f}")
        emit(f"fig7_het_t{trial}_random", us,
             f"makespan_vs_opt={rnd / opt:.3f}")
