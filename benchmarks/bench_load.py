"""Open-loop load generator against the live HTTP serving front-end.

Requests arrive on a SEEDED open-loop schedule — arrival times are drawn
up front and each request fires at its scheduled instant regardless of
how the server is doing (closed-loop generators hide queueing collapse by
slowing down with the server; open-loop ones expose it).  Three arrival
mixes:

* ``poisson``       — iid exponential inter-arrivals at ``--rate`` req/s,
  iid random prompts.
* ``bursty``        — the same offered rate delivered in simultaneous
  bursts of ``--burst`` requests (worst-case admission pressure).
* ``prefix-heavy``  — Poisson arrivals whose prompts share a long common
  prefix (the copy-on-write prefix-sharing fast path on paged backends).

Every request streams over SSE and is timed CLIENT-side: TTFT (send to
first token chunk), TPOT (mean inter-token gap after the first), and
end-to-end latency, reported as p50/p95/p99, plus goodput — completions
that met BOTH SLOs (``--slo-ttft``, ``--slo-tpot``) per second of wall
time, the serving metric that throughput alone overstates.

By default the bench self-hosts an in-process ``HydraHTTPServer`` on an
ephemeral port (``--arch``/``--smoke`` pick the model); ``--url`` points
it at an already-running ``python -m repro.launch.serve --http`` instead.

``--smoke`` is the self-asserting CI mode (``make http-smoke``): it
checks that a streamed completion is token-identical to the same prompt
decoded offline, that a mid-decode ``/v1/cancel`` frees the lane and KV
reservation within one tick (engine back to baseline), and that an
open-loop Poisson run completes with sane percentiles — then prints one
JSON line for the workflow to re-assert.

``--slo-smoke`` (``make slo-smoke``) is the scheduling A/B: the same
seeded trace — two long low-priority decodes saturating a 2-lane paged
engine, then a wave of short high-priority requests with deadlines — is
replayed against two self-hosted servers that differ ONLY in admission
policy (``fifo`` vs ``slo``).  The deadline is calibrated between the
two policies' expected latencies (geometric mean), so the run asserts
*ordering*, not absolute speed: the SLO policy must preempt the long
requests (>= 1 preempt AND resume), meet strictly more deadlines than
FIFO, and every completion — including the preempted-and-resumed longs —
must stay token-identical to offline sequential decode.
"""

from __future__ import annotations

import argparse
import http.client
import json
import threading
import time
from typing import Any, Optional
from urllib.parse import urlparse

import numpy as np


# ---------------------------------------------------------------------------
# minimal stdlib HTTP + SSE client (timed reads; no external deps)
# ---------------------------------------------------------------------------

class Client:
    def __init__(self, url: str, timeout: float = 120.0):
        p = urlparse(url)
        self.host, self.port = p.hostname, p.port
        self.timeout = timeout

    def _conn(self) -> http.client.HTTPConnection:
        return http.client.HTTPConnection(self.host, self.port,
                                          timeout=self.timeout)

    def json(self, method: str, path: str,
             body: Optional[dict] = None) -> tuple[int, dict]:
        conn = self._conn()
        try:
            payload = json.dumps(body) if body is not None else None
            conn.request(method, path, payload,
                         {"Content-Type": "application/json"})
            resp = conn.getresponse()
            return resp.status, json.loads(resp.read().decode())
        finally:
            conn.close()

    def stream(self, path: str, body: dict, *,
               stop_after: Optional[int] = None,
               on_chunk=None) -> dict:
        """POST an SSE completion and time every chunk.  ``stop_after``
        closes the socket after N token chunks (the disconnect probe);
        ``on_chunk(i, event)`` runs per token chunk (the cancel probe)."""
        conn = self._conn()
        t_send = time.perf_counter()
        out: dict[str, Any] = {"tokens": [], "chunk_times": [],
                               "final": None, "disconnected": False}
        try:
            conn.request("POST", path, json.dumps(body),
                         {"Content-Type": "application/json"})
            resp = conn.getresponse()
            if resp.status != 200:
                raise RuntimeError(f"HTTP {resp.status}: {resp.read()!r}")
            while True:
                line = resp.readline()
                if not line:
                    break
                line = line.strip()
                if not line or line.startswith(b":"):   # keep-alive ping
                    continue
                if not line.startswith(b"data: "):
                    continue
                data = line[len(b"data: "):]
                if data == b"[DONE]":
                    break
                event = json.loads(data)
                choice = event["choices"][0]
                if "token_id" in choice:
                    out["tokens"].append(choice["token_id"])
                    out["chunk_times"].append(time.perf_counter())
                    n = len(out["tokens"])
                    if on_chunk is not None:
                        on_chunk(n, event)
                    if stop_after is not None and n >= stop_after:
                        out["disconnected"] = True
                        return out          # socket closes in finally
                else:                       # terminal chunk (finish_reason)
                    out["final"] = event
        finally:
            conn.close()
        out["t_send"] = t_send
        if out["chunk_times"]:
            out["ttft_s"] = out["chunk_times"][0] - t_send
            gaps = np.diff(out["chunk_times"])
            out["tpot_s"] = float(np.mean(gaps)) if len(gaps) else 0.0
            out["e2e_s"] = out["chunk_times"][-1] - t_send
        return out


# ---------------------------------------------------------------------------
# arrival schedules + prompt mixes (seeded, drawn up front)
# ---------------------------------------------------------------------------

def make_schedule(mix: str, n: int, rate: float, burst: int,
                  rng: np.random.Generator) -> np.ndarray:
    """Arrival offsets (seconds from start), non-decreasing, length n."""
    if mix == "bursty":
        n_bursts = max(1, (n + burst - 1) // burst)
        burst_times = np.cumsum(rng.exponential(burst / rate, n_bursts))
        return np.repeat(burst_times, burst)[:n]
    # poisson and prefix-heavy share the arrival process
    return np.cumsum(rng.exponential(1.0 / rate, n))


def make_prompts(mix: str, n: int, plen: int, vocab: int,
                 rng: np.random.Generator) -> list[list[int]]:
    if mix == "prefix-heavy":
        # one long shared prefix + a short unique tail: block-aligned
        # prefixes alias physical pages copy-on-write on paged backends
        cut = max(1, (3 * plen) // 4)
        prefix = rng.integers(0, vocab, cut).tolist()
        return [prefix + rng.integers(0, vocab, plen - cut).tolist()
                for _ in range(n)]
    return [rng.integers(0, vocab, plen).tolist() for _ in range(n)]


def percentiles(xs: list[float]) -> Optional[dict]:
    if not xs:
        return None
    return {f"p{p}": round(float(np.percentile(xs, p)), 4)
            for p in (50, 95, 99)}


# ---------------------------------------------------------------------------
# the open-loop run
# ---------------------------------------------------------------------------

def run_load(client: Client, model: str, args,
             rng: np.random.Generator) -> dict:
    _, models = client.json("GET", "/v1/models")
    vocab_probe = client.json("GET", "/v1/metrics")[1]
    del vocab_probe                                   # liveness check only
    schedule = make_schedule(args.mix, args.n, args.rate, args.burst, rng)
    prompts = make_prompts(args.mix, args.n, args.prompt_len,
                           args.vocab_size, rng)
    results: list[Optional[dict]] = [None] * args.n
    errors: list[str] = []
    start = time.perf_counter() + 0.05

    slo_fields: dict[str, Any] = {}
    if getattr(args, "deadline_ms", None):
        slo_fields["deadline_ms"] = args.deadline_ms
    if getattr(args, "priority", None):
        slo_fields["priority"] = args.priority

    def fire(i: int) -> None:
        delay = start + schedule[i] - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        try:
            results[i] = client.stream(
                "/v1/completions",
                {"model": model, "prompt": prompts[i],
                 "max_tokens": args.gen, "stream": True,
                 "request_id": f"load-{args.seed}-{i}", **slo_fields})
        except Exception as e:           # one failed request must not
            errors.append(f"{i}: {e}")   # strand the whole run
    threads = [threading.Thread(target=fire, args=(i,), daemon=True)
               for i in range(args.n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=args.timeout)
    wall = time.perf_counter() - start

    done = [r for r in results if r is not None and r.get("final")]
    ttft = [r["ttft_s"] for r in done if "ttft_s" in r]
    tpot = [r["tpot_s"] for r in done if "tpot_s" in r and r["tpot_s"] > 0]
    e2e = [r["e2e_s"] for r in done if "e2e_s" in r]
    slo_ok = [r for r in done
              if r.get("ttft_s", 1e9) <= args.slo_ttft
              and r.get("tpot_s", 0.0) <= args.slo_tpot]
    n_tokens = sum(len(r["tokens"]) for r in done)
    # declared-deadline attainment (client-side): requests that finished
    # within their own deadline_ms budget — the per-request SLO the
    # scheduler optimizes, vs. the blanket --slo-ttft/--slo-tpot goodput
    deadline_attained = None
    if slo_fields.get("deadline_ms"):
        deadline_attained = sum(
            1 for r in done
            if r.get("e2e_s", 1e18) * 1000.0 <= slo_fields["deadline_ms"])
    return {
        "mix": args.mix, "n": args.n, "rate_rps": args.rate,
        "seed": args.seed, "completed": len(done), "errors": errors,
        "wall_s": round(wall, 3),
        "offered_rps": round(args.n / max(schedule[-1], 1e-9), 3),
        "throughput_tok_per_s": round(n_tokens / wall, 1) if wall else None,
        "ttft_s": percentiles(ttft),
        "tpot_s": percentiles(tpot),
        "e2e_s": percentiles(e2e),
        "slo": {"ttft_s": args.slo_ttft, "tpot_s": args.slo_tpot},
        "slo_attained": len(slo_ok),
        "goodput_rps": round(len(slo_ok) / wall, 3) if wall else None,
        "deadline_ms": slo_fields.get("deadline_ms"),
        "deadline_attained": deadline_attained,
        "models_served": [m["id"] for m in models["data"]],
    }


# ---------------------------------------------------------------------------
# self-hosted server (in-process, ephemeral port) + the smoke checks
# ---------------------------------------------------------------------------

def self_host(args):
    """Build the model in-process and serve it on an ephemeral port.
    Returns (http_server, reference_engine) — the reference engine shares
    params with the served one, for offline token-identity checks."""
    import jax

    from repro.configs import get_config
    from repro.models import api as mapi
    from repro.serving import (HydraHTTPServer, InferenceEngine,
                               MultiModelServer)

    cfg = get_config(args.arch, smoke=args.smoke)
    params = mapi.init_params(cfg, jax.random.PRNGKey(args.seed))
    # the smoke's cancel probe runs a 64-token request so there is decode
    # left to cancel — size the cache for it, not just --gen
    max_seq = args.prompt_len + max(args.gen, 64) + 8

    def make_engine():
        return InferenceEngine(cfg, params, capacity=args.capacity,
                               max_seq=max_seq, backend=args.backend,
                               model_name=args.arch)
    server = MultiModelServer({args.arch: make_engine()})
    http_srv = HydraHTTPServer(server, port=args.port)
    return http_srv, make_engine()


def smoke(args, client: Client, ref_engine, model: str) -> dict:
    out: dict[str, Any] = {}
    rng = np.random.default_rng(args.seed)
    prompt = rng.integers(0, args.vocab_size, args.prompt_len).tolist()

    # 1. SSE streaming is token-identical to offline decode (same params)
    ref = ref_engine.submit(np.asarray(prompt, np.int32), args.gen)
    ref_engine.run()
    streamed = client.stream("/v1/completions",
                             {"model": model, "prompt": prompt,
                              "max_tokens": args.gen, "stream": True})
    out["offline_tokens"] = ref.generated
    out["streamed_tokens"] = streamed["tokens"]
    out["stream_tokens_match"] = streamed["tokens"] == ref.generated
    out["stream_finish_reason"] = \
        streamed["final"]["choices"][0]["finish_reason"]

    # 2. cancel mid-decode over HTTP: the stream ends with
    #    finish_reason=cancelled and the engine is back to baseline
    #    (all lanes free, zero KV reserved) within one tick
    rid = f"smoke-cancel-{args.seed}"
    cancel_acks: list[dict] = []

    def cancel_at_three(n, _event):
        if n == 3:
            cancel_acks.append(
                client.json("POST", "/v1/cancel", {"request_id": rid})[1])
    cancelled = client.stream(
        "/v1/completions",
        {"model": model, "prompt": prompt, "max_tokens": 64,
         "stream": True, "request_id": rid},
        on_chunk=cancel_at_three)
    t_cancel = time.perf_counter()
    reason = cancelled["final"]["choices"][0]["finish_reason"]
    deadline = time.perf_counter() + 10.0
    freed = None
    while time.perf_counter() < deadline:
        eng = client.json("GET", "/v1/metrics")[1]["engines"][model]
        if eng["free_lanes"] == eng["capacity"] \
                and eng["kv_reserved_bytes"] == 0:
            freed = round(time.perf_counter() - t_cancel, 4)
            break
        time.sleep(0.01)
    out["cancel"] = {
        "acked": bool(cancel_acks and cancel_acks[0].get("cancelled")),
        "finish_reason": reason,
        "n_streamed_before_close": len(cancelled["tokens"]),
        "freed_within_s": freed,
        "tokens_saved": 64 - len(cancelled["tokens"]),
    }

    # 3. open-loop Poisson run with client-side percentiles
    out["load"] = run_load(client, model, args, rng)

    load_ok = (out["load"]["completed"] == args.n
               and not out["load"]["errors"]
               and out["load"]["ttft_s"] is not None
               and out["load"]["goodput_rps"] is not None)
    out["ok"] = bool(out["stream_tokens_match"]
                     and out["cancel"]["acked"]
                     and reason == "cancelled"
                     and out["cancel"]["n_streamed_before_close"] < 64
                     and freed is not None
                     and load_ok)
    return out


# ---------------------------------------------------------------------------
# --slo-smoke: same seeded trace under FIFO vs SLO admission (A/B)
# ---------------------------------------------------------------------------

def slo_smoke(args) -> dict:
    """Replay one seeded trace against two self-hosted servers differing
    only in admission policy; assert the SLO policy preempts, resumes,
    meets strictly more deadlines than FIFO, and stays token-identical
    to offline sequential decode (see module docstring)."""
    import math

    import jax

    from repro.configs import get_config
    from repro.models import api as mapi
    from repro.serving import (HydraHTTPServer, InferenceEngine,
                               MultiModelServer, blocks_for_rows)

    cfg = get_config(args.arch, smoke=True)
    params = mapi.init_params(cfg, jax.random.PRNGKey(args.seed))
    n_short, gen_short = 4, args.gen
    gen_long = 20 * gen_short       # the lane-hogging decode worth preempting
    plen = args.prompt_len
    max_seq = plen + gen_long + 8
    # preemption frees the LANE, not the victim's byte reservation (its KV
    # blocks stay charged for resume) — so the pool must hold both longs'
    # worst case AND the shorts', or can_admit_bytes correctly vetoes the
    # eviction as byte-blocked
    n_blocks = (2 * blocks_for_rows(plen + gen_long, 8)
                + n_short * blocks_for_rows(plen + gen_short, 8) + 2)
    rng = np.random.default_rng(args.seed)
    long_prompts = [rng.integers(0, cfg.vocab_size, plen).tolist()
                    for _ in range(2)]
    short_prompts = [rng.integers(0, cfg.vocab_size, plen).tolist()
                     for _ in range(n_short)]
    warm_prompt = rng.integers(0, cfg.vocab_size, plen).tolist()

    def make_engine(policy: str) -> InferenceEngine:
        return InferenceEngine(cfg, params, capacity=2, max_seq=max_seq,
                               backend="paged", block_size=8,
                               n_blocks=n_blocks,
                               model_name=args.arch, policy=policy)

    # offline token-identity oracle: each prompt decoded alone, in order
    expected: dict[str, list[int]] = {}
    ref = make_engine("fifo")
    for i, p in enumerate(long_prompts):
        r = ref.submit(np.asarray(p, np.int32), gen_long)
        ref.run()
        expected[f"long{i}"] = r.generated
    for i, p in enumerate(short_prompts):
        r = ref.submit(np.asarray(p, np.int32), gen_short)
        ref.run()
        expected[f"short{i}"] = r.generated

    def run_policy(policy: str, deadline_ms: Optional[float]) -> dict:
        eng = make_engine(policy)
        srv = HydraHTTPServer(MultiModelServer({args.arch: eng}),
                              port=args.port)
        srv.start()
        client = Client(srv.url, timeout=args.timeout)
        try:
            # warm every shape the trace hits: single + paired prefill
            # groups and the pooled decode step — compile must not land
            # inside a deadline window (jax caches survive per-process,
            # but the FIRST server pays them)
            warm: list[dict] = [{}, {}]

            def probe(slot):
                warm[slot] = client.stream(
                    "/v1/completions",
                    {"model": args.arch, "prompt": warm_prompt,
                     "max_tokens": gen_short, "stream": True})
            tw = [threading.Thread(target=probe, args=(i,), daemon=True)
                  for i in range(2)]
            for t in tw:
                t.start()
            for t in tw:
                t.join(timeout=args.timeout)
            if deadline_ms is None:
                # calibrate on the warm probe: the deadline sits at the
                # log-midpoint between the SLO policy's expected short
                # latency (a few idle short decodes) and FIFO's (wait out
                # most of a long decode) — asserting ordering, not speed
                ttft = warm[0].get("ttft_s", 0.05)
                tpot = max(warm[0].get("tpot_s", 0.01), 1e-4)
                est_short = max(warm[0].get("e2e_s", 0.1), 1e-3)
                est_fifo_wait = 0.85 * (ttft + (gen_long - 1) * tpot)
                deadline_ms = 1000.0 * math.sqrt(
                    3.0 * est_short * max(est_fifo_wait, 3.0 * est_short))

            results: dict[str, dict] = {}
            errors: list[str] = []
            started = [threading.Event() for _ in range(2)]

            def fire(rid, prompt, gen, extra, evt=None):
                def on_chunk(n, _e):
                    if evt is not None and n >= 3:
                        evt.set()
                try:
                    results[rid] = client.stream(
                        "/v1/completions",
                        {"model": args.arch, "prompt": prompt,
                         "max_tokens": gen, "stream": True,
                         "request_id": f"{policy}-{rid}", **extra},
                        on_chunk=on_chunk)
                except Exception as e:
                    errors.append(f"{rid}: {e}")
            threads = []
            for i in range(2):
                t = threading.Thread(
                    target=fire,
                    args=(f"long{i}", long_prompts[i], gen_long,
                          {"priority": "low"}, started[i]), daemon=True)
                t.start()
                threads.append(t)
            for evt in started:     # victims must be RUNNING with >=
                if not evt.wait(timeout=60):    # preempt_min_tokens decoded
                    errors.append("long request never started streaming")
            for i in range(n_short):
                t = threading.Thread(
                    target=fire,
                    args=(f"short{i}", short_prompts[i], gen_short,
                          {"priority": "high", "deadline_ms": deadline_ms}),
                    daemon=True)
                t.start()
                threads.append(t)
            for t in threads:
                t.join(timeout=args.timeout)

            metrics = client.json("GET", "/v1/metrics")[1]
            ring = {m["request_id"]: m
                    for m in metrics["recent_requests"][args.arch]}
            attained = sum(
                1 for i in range(n_short)
                if ring.get(f"{policy}-short{i}", {}).get("deadline_met"))
            token_ok = all(
                results.get(rid, {}).get("tokens") == toks
                for rid, toks in expected.items())
            return {"policy": policy,
                    "deadline_ms": round(deadline_ms, 1),
                    "deadline_attained": attained,
                    "n_short": n_short,
                    "n_preempted": metrics["n_preempted"],
                    "n_resumed": metrics["n_resumed"],
                    "n_shed": metrics["n_shed"],
                    "long_preemptions": [
                        ring.get(f"{policy}-long{i}", {}).get("preemptions")
                        for i in range(2)],
                    "tokens_match_offline": token_ok,
                    "errors": errors}
        finally:
            srv.stop()

    fifo = run_policy("fifo", None)
    slo = run_policy("slo", fifo["deadline_ms"])   # SAME trace, same budget
    ok = bool(not fifo["errors"] and not slo["errors"]
              and fifo["tokens_match_offline"]
              and slo["tokens_match_offline"]
              and fifo["n_preempted"] == 0
              and slo["n_preempted"] >= 1
              and slo["n_resumed"] >= 1
              and slo["deadline_attained"] > fifo["deadline_attained"])
    return {"arch": args.arch, "seed": args.seed,
            "gen_long": gen_long, "gen_short": gen_short,
            "fifo": fifo, "slo": slo, "ok": ok}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--url", default=None,
                    help="attach to a running server (default: self-host "
                    "in-process on an ephemeral port)")
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true",
                    help="self-asserting CI mode (token identity + cancel "
                    "+ Poisson percentiles); prints one JSON line")
    ap.add_argument("--slo-smoke", action="store_true",
                    help="A/B the admission policies: one seeded trace "
                    "under fifo and slo; asserts strictly higher deadline "
                    "attainment, >=1 preempt+resume, token identity")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="attach this end-to-end deadline to every load "
                    "request (reported as deadline_attained)")
    ap.add_argument("--priority", default=None,
                    choices=["high", "normal", "low"],
                    help="attach this priority tier to every load request")
    ap.add_argument("--backend", default="slot",
                    choices=["slot", "paged", "spec"])
    ap.add_argument("--mix", default="poisson",
                    choices=["poisson", "bursty", "prefix-heavy"])
    ap.add_argument("--n", type=int, default=8, help="total requests")
    ap.add_argument("--rate", type=float, default=4.0,
                    help="offered arrival rate, req/s")
    ap.add_argument("--burst", type=int, default=4,
                    help="burst size for --mix bursty")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--capacity", type=int, default=4)
    ap.add_argument("--vocab-size", type=int, default=0,
                    help="prompt id range (0: read from the model config "
                    "when self-hosting, else 1000)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--slo-ttft", type=float, default=5.0,
                    help="TTFT SLO seconds (goodput counts requests "
                    "meeting it; generous default absorbs jit compiles)")
    ap.add_argument("--slo-tpot", type=float, default=0.5,
                    help="per-token SLO seconds")
    ap.add_argument("--timeout", type=float, default=300.0)
    args = ap.parse_args()

    if args.slo_smoke:
        if args.url is not None:
            raise SystemExit("--slo-smoke self-hosts both policy servers "
                             "(token identity needs in-process params); "
                             "drop --url")
        print(json.dumps(slo_smoke(args)))
        return

    http_srv = ref_engine = None
    if args.url is None:
        http_srv, ref_engine = self_host(args)
        http_srv.start()
        url = http_srv.url
        if not args.vocab_size:
            args.vocab_size = ref_engine.cfg.vocab_size
    else:
        url = args.url
        if not args.vocab_size:
            args.vocab_size = 1000
    client = Client(url, timeout=args.timeout)
    try:
        if args.smoke:
            if ref_engine is None:
                raise SystemExit("--smoke needs the self-hosted server "
                                 "(token identity compares against the "
                                 "same in-process params); drop --url")
            # warm the jit caches so measured TTFT is serving, not compile
            client.json("POST", "/v1/completions",
                        {"model": args.arch,
                         "prompt": list(range(1, args.prompt_len + 1)),
                         "max_tokens": 2})
            out = smoke(args, client, ref_engine, args.arch)
        else:
            rng = np.random.default_rng(args.seed)
            model = args.arch
            out = run_load(client, model, args, rng)
    finally:
        if http_srv is not None:
            http_srv.stop()
    print(json.dumps(out))


if __name__ == "__main__":
    main()
