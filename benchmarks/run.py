"""Benchmark driver — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run fig8 table3

Prints ``name,us_per_call,derived`` CSV rows.
"""

from __future__ import annotations

import sys
import time

SUITES = {
    "fig7": ("benchmarks.bench_scheduler", "Fig 7  scheduler simulation"),
    "fig8": ("benchmarks.bench_end_to_end", "Fig 8  end-to-end 12 models"),
    "fig9a": ("benchmarks.bench_num_models", "Fig 9A #models sweep"),
    "fig9b": ("benchmarks.bench_num_gpus", "Fig 9B #devices sweep"),
    "fig10": ("benchmarks.bench_model_scale", "Fig 10 model scale"),
    "table3": ("benchmarks.bench_ablation", "Table 3 ablation"),
    "kernels": ("benchmarks.bench_kernels", "kernel micro-benchmarks"),
    "serving": ("benchmarks.bench_serving", "serving engine (prefill + "
                "continuous batching)"),
}


def main() -> None:
    import importlib
    which = [a for a in sys.argv[1:] if a in SUITES] or list(SUITES)
    print("name,us_per_call,derived")
    for key in which:
        mod_name, desc = SUITES[key]
        print(f"# --- {desc} ---")
        t0 = time.time()
        mod = importlib.import_module(mod_name)
        mod.run()
        print(f"# {key} done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
