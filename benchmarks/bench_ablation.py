"""Paper Table 3: ablation — spilling-only vs +SHARP vs +double-buffering.

The paper reports 13.05x / 2.3x / 1x relative runtimes on 16 models x 8
devices; the virtual-device executor reproduces the ordering (magnitudes
depend on the compute/transfer ratio, set here by link_bw)."""

from __future__ import annotations

from benchmarks.common import bert_grid_tasks, emit, run_hydra


def run():
    cfgs = {
        "spilling_only": dict(sharp=False, db=False),
        "sharp_no_db": dict(sharp=True, db=False),
        "hydra_full": dict(sharp=True, db=True),
    }
    results = {}
    ref_times = None
    for name, kw in cfgs.items():
        tasks = bert_grid_tasks(n_models=8, steps=2)
        # slow link so transfer hiding matters, as on PCIe
        orch, report = run_hydra(tasks, n_devices=8, budget=6 * 10**6,
                                 link_bw=5e8, **kw)
        if ref_times is None:
            ref_times = [[(s.fwd_runtime, s.bwd_runtime)
                          for s in m.partition.shards] for m in orch.models]
        else:
            # pin unit times to the first config's pilot measurements and
            # replay the schedule, so the three modes differ ONLY in
            # scheduling (CPU timing noise across configs otherwise swamps
            # the double-buffering delta)
            for m, times in zip(orch.models, ref_times):
                for s, (f, b) in zip(m.partition.shards, times):
                    s.fwd_runtime, s.bwd_runtime = f, b
                    s.est_runtime = f + b
            from repro.core import HydraConfig, SharpExecutor
            hc = HydraConfig(n_devices=8, device_budget_bytes=6 * 10**6,
                             link_bw=5e8, enable_sharp=kw["sharp"],
                             enable_double_buffer=kw["db"], pilot=False)
            for m in orch.models:
                m.__dict__.update(epoch=0, minibatch=0, done=False,
                                  ready_at=0.0, reserved=False,
                                  act_location=None)
            report = SharpExecutor(hc, orch.models).run()
        results[name] = report
    full = results["hydra_full"].makespan
    for name, report in results.items():
        emit(f"table3_{name}", report.makespan * 1e6,
             f"runtime_vs_hydra={report.makespan / full:.2f};"
             f"util={report.avg_utilization:.2f};"
             f"exposed_tx_s={report.exposed_transfer_time:.3f}")
