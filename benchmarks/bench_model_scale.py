"""Paper Fig 10: impact of model scale — Hydra's speedup over strict model
parallelism should stay roughly constant as models grow (more shard units,
similar per-unit times)."""

from __future__ import annotations

from benchmarks.common import baseline_reports, emit, make_loader, run_hydra
from repro.configs import get_config
from repro.core import ModelTask

SCALES = {           # (n_layers, d_model, d_ff) smoke-scale ladder
    "s": (2, 128, 256),
    "m": (4, 192, 384),
    "l": (6, 256, 512),
}


def run():
    for name, (L, d, f) in SCALES.items():
        cfg = get_config("bert-large-1b", smoke=True).replace(
            n_layers=L, d_model=d, n_heads=4, n_kv_heads=4, head_dim=d // 4,
            d_ff=f)
        tasks = [ModelTask(cfg, make_loader(cfg, seed=i), lr=1e-3, epochs=1,
                           steps_per_epoch=2, seed=i, batch=2, seq=64)
                 for i in range(8)]
        budget = 4 * 10**6 * (1 + "sml".index(name))
        orch, report = run_hydra(tasks, n_devices=8, budget=budget)
        mp = baseline_reports(orch, tasks, 8, budget)["model_parallel"]
        shards = len(orch.models[0].partition.shards)
        emit(f"fig10_scale_{name}", report.makespan * 1e6,
             f"speedup_vs_mp={mp.makespan / report.makespan:.2f};"
             f"shards={shards};util={report.avg_utilization:.2f}")
