"""Serving benchmarks: batched prefill vs the seed per-token loop, and
continuous-batching vs run-to-completion decode.

  PYTHONPATH=src python -m benchmarks.bench_serving --smoke
  PYTHONPATH=src python -m benchmarks.run serving

Rows print as ``name,us_per_call,derived`` CSV (bench harness); ``--smoke``
additionally prints a JSON summary with the prefill speedup.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.models import api
from repro.serving import InferenceEngine
from repro.training.train_loop import make_decode_step, make_prefill_into_cache


def _seed_prefill_loop(step, params, tokens, state):
    """The pre-engine serving path: one jitted decode_step per prompt token.
    ``step`` is passed in pre-jitted so every rep reuses the compiled
    program — the timed comparison is warm-vs-warm."""
    logits = None
    for i in range(tokens.shape[1]):
        logits, state = step(params, state, tokens[:, i:i + 1])
    return logits, state


def bench_prefill(arch="qwen3-0.6b", batch=4, plen=64, max_seq=96,
                  reps=3) -> dict:
    """Batched prefill-into-cache vs per-token loop: prompt tokens/sec."""
    cfg = get_config(arch, smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (batch, plen), 0,
                                cfg.vocab_size, jnp.int32)
    prefill = jax.jit(make_prefill_into_cache(cfg))
    step = jax.jit(lambda p, s, t: api.decode_step(cfg, p, s, t))

    def run_batched():
        state = api.init_decode_state(cfg, batch, max_seq)
        logits, _ = prefill(params, state, tokens)
        return jax.block_until_ready(logits)

    def run_loop():
        state = api.init_decode_state(cfg, batch, max_seq)
        logits, _ = _seed_prefill_loop(step, params, tokens, state)
        return jax.block_until_ready(logits)

    run_batched(); run_loop()                       # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        run_batched()
    batched_s = (time.perf_counter() - t0) / reps
    t0 = time.perf_counter()
    for _ in range(reps):
        run_loop()
    loop_s = (time.perf_counter() - t0) / reps

    n_tok = batch * plen
    batched_tps = n_tok / batched_s
    loop_tps = n_tok / loop_s
    speedup = batched_tps / loop_tps
    emit(f"serve_prefill_batched_{arch}", batched_s * 1e6,
         f"{batched_tps:.0f}tok/s")
    emit(f"serve_prefill_loop_{arch}", loop_s * 1e6, f"{loop_tps:.0f}tok/s")
    emit(f"serve_prefill_speedup_{arch}", 0.0, f"{speedup:.1f}x")
    return {"arch": arch, "batch": batch, "prompt_len": plen,
            "batched_tok_per_s": round(batched_tps, 1),
            "per_token_loop_tok_per_s": round(loop_tps, 1),
            "prefill_speedup": round(speedup, 2)}


def bench_continuous(arch="qwen3-0.6b", n_requests=8, capacity=4,
                     plen=32, gen=16, max_seq=64) -> dict:
    """Continuous batching (slot pool, staggered mix of lengths) vs decoding
    each request alone to completion: generated tokens/sec."""
    cfg = get_config(arch, smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    prompts = [np.asarray(jax.random.randint(
        jax.random.PRNGKey(10 + i), (plen,), 0, cfg.vocab_size, jnp.int32))
        for i in range(n_requests)]
    gens = [gen - (i % 4) for i in range(n_requests)]

    eng = InferenceEngine(cfg, params, capacity=capacity, max_seq=max_seq,
                          model_name=arch)
    for p, g in zip(prompts, gens):
        eng.submit(p, g)
    eng.run()                                       # compile everything
    eng2 = InferenceEngine(cfg, params, capacity=capacity, max_seq=max_seq,
                           model_name=arch)
    t0 = time.perf_counter()
    for p, g in zip(prompts, gens):
        eng2.submit(p, g)
    done = eng2.run()
    engine_s = time.perf_counter() - t0
    n_gen = sum(len(r.generated) for r in done)

    prefill = jax.jit(make_prefill_into_cache(cfg))
    decode = jax.jit(make_decode_step(cfg))

    def run_sequential():
        for p, g in zip(prompts, gens):
            state = api.init_decode_state(cfg, 1, max_seq)
            logits, state = prefill(params, state, jnp.asarray(p)[None, :])
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            for _ in range(g - 1):
                tok, state = decode(params, state, tok)
            jax.block_until_ready(tok)

    run_sequential()                                # compile (warm-vs-warm)
    t0 = time.perf_counter()
    run_sequential()
    seq_s = time.perf_counter() - t0

    engine_tps = n_gen / engine_s
    seq_tps = n_gen / seq_s
    emit(f"serve_continuous_{arch}", engine_s * 1e6, f"{engine_tps:.0f}tok/s")
    emit(f"serve_sequential_{arch}", seq_s * 1e6, f"{seq_tps:.0f}tok/s")
    return {"arch": arch, "n_requests": n_requests, "capacity": capacity,
            "engine_tok_per_s": round(engine_tps, 1),
            "sequential_tok_per_s": round(seq_tps, 1),
            "decode_speedup": round(engine_tps / seq_tps, 2)}


def bench_paged(arch="qwen3-0.6b", n_requests=12, capacity=12, plen=8,
                gen=8, max_seq=128, block_size=16,
                budget_slots=3) -> dict:
    """Paged vs slot-pool admission under ONE KV byte budget.

    The slot pool charges ``max_seq`` rows per request, so a budget worth
    ``budget_slots`` slots caps concurrency at ``budget_slots`` no matter
    how short the prompts are; block-granular paging charges the actual
    prompt + decode extent, so the same budget admits strictly more
    short-prompt requests.  Reports peak admitted concurrency and peak KV
    bytes for both engines (peak page bytes must stay <= budget —
    tests/test_paging.py asserts it; the bench reports it).
    """
    cfg = get_config(arch, smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    prompts = [np.asarray(jax.random.randint(
        jax.random.PRNGKey(30 + i), (plen,), 0, cfg.vocab_size, jnp.int32))
        for i in range(n_requests)]
    budget = budget_slots * api.decode_state_bytes(cfg, 1, max_seq)

    def drive(paged: bool):
        eng = InferenceEngine(cfg, params, capacity=capacity,
                              max_seq=max_seq, kv_budget_bytes=budget,
                              paged=paged, block_size=block_size,
                              model_name=arch)
        for p in prompts:
            eng.submit(p, gen)
        t0 = time.perf_counter()
        done = eng.run()
        wall = time.perf_counter() - t0
        n_gen = sum(len(r.generated) for r in done)
        return eng, n_gen / wall

    slot_eng, slot_tps = drive(paged=False)
    paged_eng, paged_tps = drive(paged=True)
    slot_sum, paged_sum = slot_eng.summary(), paged_eng.summary()
    emit(f"serve_paged_concurrency_{arch}", 0.0,
         f"{paged_sum['peak_concurrency']}vs{slot_sum['peak_concurrency']}")
    emit(f"serve_paged_kv_peak_{arch}", 0.0,
         f"{paged_sum['kv_page_peak_bytes']}B")
    emit(f"serve_paged_{arch}", 0.0, f"{paged_tps:.0f}tok/s")
    emit(f"serve_slot_{arch}", 0.0, f"{slot_tps:.0f}tok/s")
    return {"arch": arch, "n_requests": n_requests, "capacity": capacity,
            "prompt_len": plen, "gen": gen, "max_seq": max_seq,
            "block_size": block_size,
            "kv_budget_bytes": budget,
            "slot_peak_concurrency": slot_sum["peak_concurrency"],
            "paged_peak_concurrency": paged_sum["peak_concurrency"],
            "concurrency_gain": round(paged_sum["peak_concurrency"]
                                      / max(slot_sum["peak_concurrency"], 1),
                                      2),
            "slot_kv_peak_bytes": slot_sum["kv_peak_bytes"],
            "paged_kv_reserved_peak_bytes": paged_sum["kv_peak_bytes"],
            "paged_kv_page_peak_bytes": paged_sum["kv_page_peak_bytes"],
            "page_peak_within_budget":
                paged_sum["kv_page_peak_bytes"] <= budget,
            "slot_tok_per_s": round(slot_tps, 1),
            "paged_tok_per_s": round(paged_tps, 1)}


def run() -> None:
    """Bench-harness entry (benchmarks.run suite 'serving')."""
    bench_prefill()
    bench_continuous()
    bench_paged()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes + JSON summary")
    ap.add_argument("--paged", action="store_true",
                    help="paged vs slot-pool admission under one KV budget")
    ap.add_argument("--arch", default="qwen3-0.6b")
    args = ap.parse_args()
    if args.paged:
        print(json.dumps({"paged": bench_paged(arch=args.arch)}))
    elif args.smoke:
        out = {"prefill": bench_prefill(arch=args.arch),
               "continuous": bench_continuous(arch=args.arch)}
        print(json.dumps(out))
    else:
        bench_prefill(arch=args.arch, batch=8, plen=128, max_seq=160)
        bench_continuous(arch=args.arch, n_requests=16, capacity=8)


if __name__ == "__main__":
    main()
