"""Serving benchmarks: batched prefill vs the seed per-token loop, and
continuous-batching vs run-to-completion decode.

  PYTHONPATH=src python -m benchmarks.bench_serving --smoke
  PYTHONPATH=src python -m benchmarks.run serving

Rows print as ``name,us_per_call,derived`` CSV (bench harness); ``--smoke``
additionally prints a JSON summary with the prefill speedup.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.models import api
from repro.serving import InferenceEngine
from repro.training.train_loop import make_decode_step, make_prefill_into_cache


def _seed_prefill_loop(step, params, tokens, state):
    """The pre-engine serving path: one jitted decode_step per prompt token.
    ``step`` is passed in pre-jitted so every rep reuses the compiled
    program — the timed comparison is warm-vs-warm."""
    logits = None
    for i in range(tokens.shape[1]):
        logits, state = step(params, state, tokens[:, i:i + 1])
    return logits, state


def bench_prefill(arch="qwen3-0.6b", batch=4, plen=64, max_seq=96,
                  reps=3) -> dict:
    """Batched prefill-into-cache vs per-token loop: prompt tokens/sec."""
    cfg = get_config(arch, smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (batch, plen), 0,
                                cfg.vocab_size, jnp.int32)
    prefill = jax.jit(make_prefill_into_cache(cfg))
    step = jax.jit(lambda p, s, t: api.decode_step(cfg, p, s, t))

    def run_batched():
        state = api.init_decode_state(cfg, batch, max_seq)
        logits, _ = prefill(params, state, tokens)
        return jax.block_until_ready(logits)

    def run_loop():
        state = api.init_decode_state(cfg, batch, max_seq)
        logits, _ = _seed_prefill_loop(step, params, tokens, state)
        return jax.block_until_ready(logits)

    run_batched(); run_loop()                       # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        run_batched()
    batched_s = (time.perf_counter() - t0) / reps
    t0 = time.perf_counter()
    for _ in range(reps):
        run_loop()
    loop_s = (time.perf_counter() - t0) / reps

    n_tok = batch * plen
    batched_tps = n_tok / batched_s
    loop_tps = n_tok / loop_s
    speedup = batched_tps / loop_tps
    emit(f"serve_prefill_batched_{arch}", batched_s * 1e6,
         f"{batched_tps:.0f}tok/s")
    emit(f"serve_prefill_loop_{arch}", loop_s * 1e6, f"{loop_tps:.0f}tok/s")
    emit(f"serve_prefill_speedup_{arch}", 0.0, f"{speedup:.1f}x")
    return {"arch": arch, "batch": batch, "prompt_len": plen,
            "batched_tok_per_s": round(batched_tps, 1),
            "per_token_loop_tok_per_s": round(loop_tps, 1),
            "prefill_speedup": round(speedup, 2)}


def bench_continuous(arch="qwen3-0.6b", n_requests=8, capacity=4,
                     plen=32, gen=16, max_seq=64) -> dict:
    """Continuous batching (slot pool, staggered mix of lengths) vs decoding
    each request alone to completion: generated tokens/sec."""
    cfg = get_config(arch, smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    prompts = [np.asarray(jax.random.randint(
        jax.random.PRNGKey(10 + i), (plen,), 0, cfg.vocab_size, jnp.int32))
        for i in range(n_requests)]
    gens = [gen - (i % 4) for i in range(n_requests)]

    eng = InferenceEngine(cfg, params, capacity=capacity, max_seq=max_seq,
                          model_name=arch)
    for p, g in zip(prompts, gens):
        eng.submit(p, g)
    eng.run()                                       # compile everything
    eng2 = InferenceEngine(cfg, params, capacity=capacity, max_seq=max_seq,
                           model_name=arch)
    t0 = time.perf_counter()
    for p, g in zip(prompts, gens):
        eng2.submit(p, g)
    done = eng2.run()
    engine_s = time.perf_counter() - t0
    n_gen = sum(len(r.generated) for r in done)

    prefill = jax.jit(make_prefill_into_cache(cfg))
    decode = jax.jit(make_decode_step(cfg))

    def run_sequential():
        for p, g in zip(prompts, gens):
            state = api.init_decode_state(cfg, 1, max_seq)
            logits, state = prefill(params, state, jnp.asarray(p)[None, :])
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            for _ in range(g - 1):
                tok, state = decode(params, state, tok)
            jax.block_until_ready(tok)

    run_sequential()                                # compile (warm-vs-warm)
    t0 = time.perf_counter()
    run_sequential()
    seq_s = time.perf_counter() - t0

    engine_tps = n_gen / engine_s
    seq_tps = n_gen / seq_s
    emit(f"serve_continuous_{arch}", engine_s * 1e6, f"{engine_tps:.0f}tok/s")
    emit(f"serve_sequential_{arch}", seq_s * 1e6, f"{seq_tps:.0f}tok/s")
    return {"arch": arch, "n_requests": n_requests, "capacity": capacity,
            "engine_tok_per_s": round(engine_tps, 1),
            "sequential_tok_per_s": round(seq_tps, 1),
            "decode_speedup": round(engine_tps / seq_tps, 2)}


def bench_paged(arch="qwen3-0.6b", n_requests=12, capacity=12, plen=8,
                gen=8, max_seq=128, block_size=16,
                budget_slots=3) -> dict:
    """Paged vs slot-pool admission under ONE KV byte budget.

    The slot pool charges ``max_seq`` rows per request, so a budget worth
    ``budget_slots`` slots caps concurrency at ``budget_slots`` no matter
    how short the prompts are; block-granular paging charges the actual
    prompt + decode extent, so the same budget admits strictly more
    short-prompt requests.  Reports peak admitted concurrency and peak KV
    bytes for both engines (peak page bytes must stay <= budget —
    tests/test_paging.py asserts it; the bench reports it).

    A second comparison re-runs the paged engine fp vs ``kv_dtype="int8"``
    on the seeded token-stability suite (the same workload
    tests/test_paging.py gates — smoke-model logit margins are thin
    enough that arbitrary prompts flip the odd argmax under quantization,
    so the identity bar is pinned to seeds where fp and int8 agree
    exactly) under ONE deliberately tight byte budget (worth 3 fp
    blocks): int8 blocks are strictly smaller (1-byte KV + amortized
    per-row scale vs 2-byte bf16), so the quantized pool must admit
    strictly more concurrent lanes AND stay token-identical (asserted —
    this is the ``make paged-smoke`` acceptance bar for the quantized
    cache; CI re-asserts from the JSON).
    """
    cfg = get_config(arch, smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    prompts = [np.asarray(jax.random.randint(
        jax.random.PRNGKey(30 + i), (plen,), 0, cfg.vocab_size, jnp.int32))
        for i in range(n_requests)]
    budget = budget_slots * api.decode_state_bytes(cfg, 1, max_seq)

    def drive(paged: bool, kv_dtype=None, kv_budget=budget,
              work=None):
        eng = InferenceEngine(cfg, params, capacity=capacity,
                              max_seq=max_seq, kv_budget_bytes=kv_budget,
                              paged=paged, block_size=block_size,
                              kv_dtype=kv_dtype, model_name=arch)
        reqs = [eng.submit(p, g) for p, g in
                (work or [(p, gen) for p in prompts])]
        t0 = time.perf_counter()
        eng.run()
        wall = time.perf_counter() - t0
        toks = [r.generated for r in reqs]
        return eng, sum(map(len, toks)) / wall, toks

    slot_eng, slot_tps, _ = drive(paged=False)
    paged_eng, paged_tps, _ = drive(paged=True)
    slot_sum, paged_sum = slot_eng.summary(), paged_eng.summary()

    # int8 KV vs fp under one tight budget, on the seeded stability
    # suite (each request spans <= block_size rows -> exactly one block)
    fp_block = api.kv_block_bytes(cfg, block_size)
    int8_block = api.kv_block_bytes(cfg, block_size, "int8")
    tight = 3 * fp_block
    stable = [(np.asarray(jax.random.randint(
        jax.random.PRNGKey(900 + i), (4 + i,), 0, cfg.vocab_size,
        jnp.int32)), 6) for i in range(6)]
    fp_eng, _, fp_toks = drive(paged=True, kv_budget=tight, work=stable)
    q_eng, _, q_toks = drive(paged=True, kv_dtype="int8",
                             kv_budget=tight, work=stable)
    fp_peak = fp_eng.summary()["peak_concurrency"]
    q_peak = q_eng.summary()["peak_concurrency"]
    assert q_toks == fp_toks, "int8 KV decode diverged from fp paged decode"
    assert q_peak > fp_peak, \
        (f"int8 KV admitted {q_peak} lanes <= fp {fp_peak} under one "
         f"budget of {tight}B ({fp_block}B fp vs {int8_block}B int8 blocks)")
    emit(f"serve_paged_int8_concurrency_{arch}", 0.0, f"{q_peak}vs{fp_peak}")
    emit(f"serve_paged_concurrency_{arch}", 0.0,
         f"{paged_sum['peak_concurrency']}vs{slot_sum['peak_concurrency']}")
    emit(f"serve_paged_kv_peak_{arch}", 0.0,
         f"{paged_sum['kv_page_peak_bytes']}B")
    emit(f"serve_paged_{arch}", 0.0, f"{paged_tps:.0f}tok/s")
    emit(f"serve_slot_{arch}", 0.0, f"{slot_tps:.0f}tok/s")
    return {"arch": arch, "n_requests": n_requests, "capacity": capacity,
            "prompt_len": plen, "gen": gen, "max_seq": max_seq,
            "block_size": block_size,
            "kv_budget_bytes": budget,
            "slot_peak_concurrency": slot_sum["peak_concurrency"],
            "paged_peak_concurrency": paged_sum["peak_concurrency"],
            "concurrency_gain": round(paged_sum["peak_concurrency"]
                                      / max(slot_sum["peak_concurrency"], 1),
                                      2),
            "slot_kv_peak_bytes": slot_sum["kv_peak_bytes"],
            "paged_kv_reserved_peak_bytes": paged_sum["kv_peak_bytes"],
            "paged_kv_page_peak_bytes": paged_sum["kv_page_peak_bytes"],
            "page_peak_within_budget":
                paged_sum["kv_page_peak_bytes"] <= budget,
            "slot_tok_per_s": round(slot_tps, 1),
            "paged_tok_per_s": round(paged_tps, 1),
            "int8_kv": {
                "kv_budget_bytes": tight,
                "fp_block_bytes": fp_block,
                "int8_block_bytes": int8_block,
                "block_shrink": round(fp_block / int8_block, 2),
                "fp_peak_concurrency": fp_peak,
                "int8_peak_concurrency": q_peak,
                "tokens_identical": q_toks == fp_toks,
                "int8_kv_dtype": q_eng.summary()["kv_dtype"]}}


def bench_prefix_share(arch="qwen3-0.6b", n_requests=6, prefix_blocks=8,
                       tail=2, gen=4, max_seq=64, block_size=4,
                       budget_requests=2) -> dict:
    """Copy-on-write prefix sharing vs unshared paged admission, ONE budget.

    ``n_requests`` share a ``prefix_blocks``-block common prompt prefix;
    half carry distinct tails (full-block aliasing) and half repeat the
    first tail exactly (identical prompts — those alias the partial
    boundary block too, so the first decode write past the shared extent
    exercises COPY-ON-WRITE).  Unshared paging charges every request its
    full extent, so a budget worth ``budget_requests`` requests caps
    concurrency there; sharing charges only unshared blocks, so the same
    budget admits strictly more (asserted — this is the ``make
    backend-smoke`` acceptance bar), with token-identical outputs, a
    block-reuse ratio > 1, and at least one COW copy.
    """
    cfg = get_config(arch, smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    from repro.serving import blocks_for_rows
    key = jax.random.PRNGKey(77)
    prefix = np.asarray(jax.random.randint(
        key, (prefix_blocks * block_size,), 0, cfg.vocab_size, jnp.int32))
    # i % 2 == 1 repeats tail 80+i-1 -> adjacent identical prompts; the
    # duplicate aliases the donor's boundary block and must COW it at its
    # first decode write
    prompts = [np.concatenate([prefix, np.asarray(jax.random.randint(
        jax.random.PRNGKey(80 + i - (i % 2)), (tail,), 0, cfg.vocab_size,
        jnp.int32))]) for i in range(n_requests)]
    worst = blocks_for_rows(len(prompts[0]) + gen - 1, block_size)
    budget = budget_requests * worst * api.kv_block_bytes(cfg, block_size)

    def drive(share: bool):
        eng = InferenceEngine(cfg, params, capacity=n_requests,
                              max_seq=max_seq, backend="paged",
                              block_size=block_size, prefix_share=share,
                              kv_budget_bytes=budget, model_name=arch)
        reqs = [eng.submit(p, gen) for p in prompts]
        eng.run()
        return eng.summary(), [r.generated for r in reqs]

    base_sum, base_toks = drive(share=False)
    share_sum, share_toks = drive(share=True)
    assert share_toks == base_toks, \
        "prefix-shared decode diverged from unshared paged decode"
    reuse = (share_sum["shared_block_hits"] + share_sum["kv_block_allocs"]) \
        / share_sum["kv_block_allocs"]
    assert reuse > 1, f"no block reuse on a common-prefix workload: {reuse}"
    assert share_sum["cow_copies"] > 0, \
        "duplicate prompts never copied their shared boundary block — " \
        "the COW path did not run"
    assert share_sum["peak_concurrency"] > base_sum["peak_concurrency"], \
        (f"sharing admitted {share_sum['peak_concurrency']} <= unshared "
         f"{base_sum['peak_concurrency']} under budget {budget}")
    assert share_sum["kv_page_peak_bytes"] <= budget
    emit(f"serve_prefix_share_concurrency_{arch}", 0.0,
         f"{share_sum['peak_concurrency']}vs{base_sum['peak_concurrency']}")
    emit(f"serve_prefix_share_reuse_{arch}", 0.0, f"{reuse:.2f}x")
    return {"arch": arch, "n_requests": n_requests,
            "prefix_len": int(prefix.shape[0]), "tail": tail, "gen": gen,
            "block_size": block_size, "kv_budget_bytes": budget,
            "shared_block_ratio": round(reuse, 2),
            "shared_block_hits": share_sum["shared_block_hits"],
            "cow_copies": share_sum["cow_copies"],
            "unshared_peak_concurrency": base_sum["peak_concurrency"],
            "shared_peak_concurrency": share_sum["peak_concurrency"],
            "unshared_kv_page_peak_bytes": base_sum["kv_page_peak_bytes"],
            "shared_kv_page_peak_bytes": share_sum["kv_page_peak_bytes"],
            "page_peak_within_budget":
                share_sum["kv_page_peak_bytes"] <= budget,
            "tokens_identical": share_toks == base_toks}


def bench_spec(arch="qwen3-0.6b", draft_arch=None, n_requests=6,
               plen=12, gen=16, max_seq=64, draft_k=4,
               block_size=8) -> dict:
    """Speculative decode vs plain decode on BOTH inner backends, one
    workload.

    The draft defaults to the target's own weights (*self-draft*): greedy
    drafting then agrees with the target at every position, so every
    verify forward accepts all k drafts — the mechanical upper bound that
    makes the smoke assertions deterministic: outputs token-identical to
    the non-spec baseline, accept-rate reported, and per-lane target
    verify steps strictly fewer than generated tokens (``make
    spec-smoke``; CI re-asserts from the JSON).  Pass a real smaller
    ``draft_arch`` to measure true accept rates.

    On the paged inner a third run activates the FUSED multi-query
    paged-verify kernel (``verify_impl="pallas"`` on TPU, interpret mode
    elsewhere) — all k+1 verify positions walk the block tables inside
    one kernel instead of gather-then-attend — and must stay
    token-identical to both the jnp-verify spec run and the plain
    baseline (the fused-verify half of the ``make spec-smoke`` bar).
    """
    cfg = get_config(arch, smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    if draft_arch is None or draft_arch == arch:
        draft_cfg, draft_params = cfg, params
        draft_name = f"{arch} (self-draft)"
    else:
        draft_cfg = get_config(draft_arch, smoke=True)
        draft_params = api.init_params(draft_cfg, jax.random.PRNGKey(1))
        draft_name = draft_arch
    prompts = [np.asarray(jax.random.randint(
        jax.random.PRNGKey(90 + i), (plen,), 0, cfg.vocab_size, jnp.int32))
        for i in range(n_requests)]
    gens = [gen - (i % 3) for i in range(n_requests)]

    def drive(backend, **kw):
        eng = InferenceEngine(cfg, params, capacity=4, max_seq=max_seq,
                              backend=backend, block_size=block_size,
                              model_name=arch, **kw)
        reqs = [eng.submit(p, g) for p, g in zip(prompts, gens)]
        t0 = time.perf_counter()
        eng.run()
        wall = time.perf_counter() - t0
        toks = [r.generated for r in reqs]
        return eng.summary(), toks, sum(map(len, toks)) / wall

    out = {"arch": arch, "draft_model": draft_name, "draft_k": draft_k,
           "n_requests": n_requests, "prompt_len": plen, "gen": gen}
    for inner in ("slot", "paged"):
        base_sum, base_toks, base_tps = drive(inner)
        spec_sum, spec_toks, spec_tps = drive(
            "spec", spec_inner=inner, draft_cfg=draft_cfg,
            draft_params=draft_params, draft_k=draft_k)
        assert spec_toks == base_toks, \
            f"spec decode over {inner} diverged from plain {inner} decode"
        n_gen = sum(map(len, spec_toks))
        assert spec_sum["target_steps"] < spec_sum["spec_tokens"], \
            (f"{inner}: {spec_sum['target_steps']} target steps for "
             f"{spec_sum['spec_tokens']} spec tokens — speculation saved "
             "nothing")
        emit(f"serve_spec_{inner}_{arch}", 0.0,
             f"{spec_sum['accepted_tokens_per_target_step']}tok/step")
        out[inner] = {
            "tokens_identical": spec_toks == base_toks,
            "n_generated": n_gen,
            "target_steps": spec_sum["target_steps"],
            "spec_rounds": spec_sum["spec_rounds"],
            "accept_rate": spec_sum["accepted_tokens_per_target_step"],
            "draft_accept_rate": spec_sum["draft_accept_rate"],
            "target_steps_lt_tokens":
                spec_sum["target_steps"] < spec_sum["spec_tokens"],
            "baseline_tok_per_s": round(base_tps, 1),
            "spec_tok_per_s": round(spec_tps, 1),
            "baseline_decode_steps": base_sum["decode_steps"],
        }
        if inner == "paged":
            impl = "pallas" if jax.default_backend() == "tpu" \
                else "pallas_interpret"
            fused_sum, fused_toks, fused_tps = drive(
                "spec", spec_inner=inner, draft_cfg=draft_cfg,
                draft_params=draft_params, draft_k=draft_k,
                verify_impl=impl)
            assert fused_toks == base_toks, \
                "fused paged verify diverged from the plain paged baseline"
            assert fused_toks == spec_toks, \
                "fused paged verify diverged from jnp-verify spec decode"
            assert fused_sum["target_steps"] < fused_sum["spec_tokens"]
            emit(f"serve_spec_fused_verify_{arch}", 0.0,
                 f"{fused_sum['accepted_tokens_per_target_step']}tok/step")
            out[inner]["fused_verify"] = {
                "verify_impl": impl,
                "tokens_identical": fused_toks == base_toks
                and fused_toks == spec_toks,
                "target_steps": fused_sum["target_steps"],
                "accept_rate":
                    fused_sum["accepted_tokens_per_target_step"],
                "spec_tok_per_s": round(fused_tps, 1),
            }
    return out


def bench_tiered_weights(arch="qwen3-0.6b", n_models=3, plen=8, gen=6,
                         max_seq=64, block_size=8,
                         part_budget=3_200_000) -> dict:
    """Shard-granular weight residency: N models served under ONE ledger
    budget that whole-model promotion could fit only ``budget // model``
    of (ROADMAP item 3a).

    Each model pins roughly half its shards hot (``hot_bytes``) and
    streams the rest through the serve loop's double buffer — the SHARP
    train pattern applied to decode — with the cross-model LRU
    coordinator demoting idle pins under pressure.  Self-asserting: every
    model's tokens are identical to a fully-resident warm engine, the
    peak count of concurrently-resident models strictly exceeds the
    whole-model bound, the ledger never exceeds its budget
    (``_check_budget`` raises otherwise), and a full drain returns every
    weight and KV byte to baseline.
    """
    from repro.core import partitioner as pt
    from repro.core import shard_graph as sg
    from repro.core.spilling import DeviceMemory, HostModelStore
    from repro.optim import optimizers as opt
    from repro.serving.residency import (ResidencyCoordinator,
                                         ShardResidentParams)
    cfg = get_config(arch, smoke=True)
    shard_plan = sg.build_plan(cfg)
    prompts = [np.asarray(jax.random.randint(
        jax.random.PRNGKey(40 + i), (plen,), 0, cfg.vocab_size, jnp.int32))
        for i in range(n_models)]

    # distinct weights per model (seed i); a tight partition budget forces
    # the multi-shard layout shard streaming needs
    stores, partitions, all_params = [], [], []
    for i in range(n_models):
        params = api.init_params(cfg, jax.random.PRNGKey(i))
        host = sg.prepare_host_params(cfg, jax.tree.map(np.asarray, params))
        partition = pt.partition(cfg, host, shard_plan,
                                 budget_bytes=part_budget, batch=1,
                                 seq=max_seq, train=False)
        stores.append(HostModelStore(cfg, shard_plan, params,
                                     opt.OptimizerConfig(grad_clip=0.0),
                                     partition))
        partitions.append(partition)
        all_params.append(params)

    model_bytes = sum(stores[0].shard_transfer_bytes(s, train=False)
                      for s in partitions[0].shards)
    # fits TWO whole models (plus KV slack), so whole-model promotion
    # serves at most 2 concurrently; shard residency must beat that
    budget = 2 * model_bytes + 512 * 1024
    whole_model_fit = budget // model_bytes
    ledger = DeviceMemory(-1, budget_bytes=budget)
    coord = ResidencyCoordinator(ledger)

    engines, sources, reqs = [], [], []
    for i in range(n_models):
        src = ShardResidentParams(cfg, stores[i], partitions[i], ledger,
                                  hot_bytes=model_bytes // 2,
                                  name=f"{arch}#{i}")
        coord.register(src)
        eng = InferenceEngine(cfg, None, capacity=1, max_seq=max_seq,
                              backend="paged", block_size=block_size,
                              ledger=ledger, policy="fifo",
                              model_name=f"{arch}#{i}", param_source=src)
        sources.append(src)
        engines.append(eng)
        reqs.append(eng.submit(prompts[i], gen))

    # round-robin the engines (the session's serve_tick shape) and track
    # how many models hold pinned weights at once
    peak_resident = 0
    t0 = time.perf_counter()
    while any(e.has_work() for e in engines):
        for eng in engines:
            if eng.has_work():
                eng.step()
        peak_resident = max(peak_resident, sum(
            1 for s in sources if s.hot_resident_bytes > 0))
    wall = time.perf_counter() - t0

    toks = [r.generated for r in reqs]
    refs = []
    for i in range(n_models):
        warm = InferenceEngine(cfg, all_params[i], capacity=1,
                               max_seq=max_seq, backend="paged",
                               block_size=block_size, policy="fifo")
        r = warm.submit(prompts[i], gen)
        warm.run()
        refs.append(r.generated)
    assert toks == refs, \
        "shard-resident decode diverged from fully-resident decode"
    assert peak_resident > whole_model_fit, \
        (f"only {peak_resident} models concurrently resident — no better "
         f"than whole-model promotion's {whole_model_fit} under "
         f"{budget} B")
    stream_bytes = sum(s.stream_promoted_bytes for s in sources)
    assert stream_bytes > 0, "no shard ever streamed — hot pins fit " \
        "everything; tighten the budget"
    # drain: unpin every model, every ledger term back to baseline
    for s in sources:
        s.demote_all()
    assert ledger.used_bytes() == 0 and ledger.host_kv_bytes == 0
    emit(f"serve_tiered_models_{arch}", wall * 1e6,
         f"{peak_resident}vs{whole_model_fit}")
    return {"arch": arch, "n_models": n_models,
            "model_weight_bytes": model_bytes,
            "ledger_budget_bytes": budget,
            "whole_model_fit": int(whole_model_fit),
            "peak_resident_models": peak_resident,
            "models_served": len(toks),
            "tokens_identical": toks == refs,
            "stream_promoted_bytes": stream_bytes,
            "hot_demotions": sum(s.n_hot_demotions for s in sources),
            "ledger_drained": ledger.used_bytes() == 0}


def bench_tiered_kv(arch="qwen3-0.6b", n_low=2, n_high=2, plen=8,
                    gen_low=16, gen_high=4, max_seq=64,
                    block_size=8) -> dict:
    """Host-DRAM KV page demotion under byte-scarce preemption (ROADMAP
    item 3b).

    One budget worth ~7 KV blocks, two lanes: the running low-priority
    longs reserve 6, so a high-priority arrival's 2-block reservation is
    byte-blocked.  Untiered paging cannot preempt its way out (a parked
    victim keeps its device reservation — the bytes guard refuses), so
    admitted concurrency stalls at the lanes.  Tiered paging demotes the
    victim's pages to the host pool at preemption, freeing real device
    bytes, admits the high, and prefetches the pages back before resume —
    strictly more peak live requests (active + parked), token-identical,
    with host<->device traffic and the prefetch hit rate reported.
    """
    from repro.core.spilling import DeviceMemory
    from repro.serving import blocks_for_rows
    cfg = get_config(arch, smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    block_bytes = api.kv_block_bytes(cfg, block_size)
    budget = 7 * block_bytes
    low_prompts = [np.asarray(jax.random.randint(
        jax.random.PRNGKey(50 + i), (plen,), 0, cfg.vocab_size, jnp.int32))
        for i in range(n_low)]
    high_prompts = [np.asarray(jax.random.randint(
        jax.random.PRNGKey(70 + i), (plen,), 0, cfg.vocab_size, jnp.int32))
        for i in range(n_high)]

    def drive(tiered: bool):
        ledger = DeviceMemory(-1, budget_bytes=budget)
        eng = InferenceEngine(cfg, params, capacity=2, max_seq=max_seq,
                              backend="paged", block_size=block_size,
                              ledger=ledger, policy="slo",
                              tiered_kv=tiered, model_name=arch)
        lows = [eng.submit(p, gen_low, priority="low")
                for p in low_prompts]
        for _ in range(3):
            eng.step()
        highs = [eng.submit(p, gen_high, priority="high",
                            deadline_ms=60_000.0) for p in high_prompts]
        t0 = time.perf_counter()
        eng.run()
        wall = time.perf_counter() - t0
        toks = [r.generated for r in lows + highs]
        assert ledger.kv_reserved_bytes == 0 and ledger.host_kv_bytes == 0
        return eng.summary(), toks, ledger, wall

    base_sum, base_toks, _, base_wall = drive(tiered=False)
    tier_sum, tier_toks, tier_led, tier_wall = drive(tiered=True)
    assert tier_toks == base_toks, \
        "tiered decode diverged from untiered paged decode"
    assert tier_sum["peak_live_requests"] > base_sum["peak_live_requests"], \
        (f"tiering admitted no extra live requests: "
         f"{tier_sum['peak_live_requests']} <= "
         f"{base_sum['peak_live_requests']} under {budget} B")
    assert tier_sum["kv_demoted_bytes"] > 0
    assert tier_sum["kv_prefetched_bytes"] > 0
    emit(f"serve_tiered_kv_live_{arch}", 0.0,
         f"{tier_sum['peak_live_requests']}vs"
         f"{base_sum['peak_live_requests']}")
    emit(f"serve_tiered_kv_traffic_{arch}", 0.0,
         f"{tier_sum['kv_demoted_bytes'] + tier_sum['kv_prefetched_bytes']}B")
    return {"arch": arch, "kv_budget_bytes": budget,
            "block_bytes": block_bytes, "capacity": 2,
            "n_low": n_low, "n_high": n_high,
            "untiered_peak_live_requests": base_sum["peak_live_requests"],
            "tiered_peak_live_requests": tier_sum["peak_live_requests"],
            "untiered_preemptions": base_sum["n_preempted"],
            "tiered_preemptions": tier_sum["n_preempted"],
            "tokens_identical": tier_toks == base_toks,
            # satellite: host<->device transfer accounting + hit rate
            "kv_demoted_bytes": tier_sum["kv_demoted_bytes"],
            "kv_prefetched_bytes": tier_sum["kv_prefetched_bytes"],
            "host_pool_peak_blocks": tier_sum["host_pool_peak_blocks"],
            "prefetch_hits": tier_sum["prefetch_hits"],
            "prefetch_misses": tier_sum["prefetch_misses"],
            "prefetch_hit_rate": tier_sum["prefetch_hit_rate"],
            "untiered_wall_s": round(base_wall, 4),
            "tiered_wall_s": round(tier_wall, 4)}


# one servable arch per family the backend smoke exercises (encoder-decoder
# families are not servable; vlm shares the transformer paths with dense)
_SMOKE_FAMILY_ARCHS = {"dense": "qwen3-0.6b", "ssm": "xlstm-350m",
                       "hybrid": "zamba2-1.2b", "moe": "mixtral-8x22b"}


def bench_backends(plen=8, gen=6, n_requests=4, max_seq=64) -> dict:
    """Every smoke family through each backend its FamilySpec declares:
    slot for all, paged too where ``paging`` is declared — asserting the
    backends agree token-for-token."""
    from repro.models.registry import spec as family_spec
    out = {}
    for family, arch in _SMOKE_FAMILY_ARCHS.items():
        cfg = get_config(arch, smoke=True)
        spec = family_spec(cfg)
        backends = ["slot"] + (["paged"] if spec.paging else [])
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        prompts = [np.asarray(jax.random.randint(
            jax.random.PRNGKey(60 + i), (plen,), 0, cfg.vocab_size,
            jnp.int32)) for i in range(n_requests)]
        toks, rec = {}, {"backends": backends}
        for name in backends:
            eng = InferenceEngine(cfg, params, capacity=n_requests,
                                  max_seq=max_seq, backend=name,
                                  model_name=arch)
            reqs = [eng.submit(p, gen) for p in prompts]
            t0 = time.perf_counter()
            eng.run()
            wall = time.perf_counter() - t0
            toks[name] = [r.generated for r in reqs]
            s = eng.summary()
            assert s["backend"] == name
            rec[name] = {"decode_tok_per_s": s["decode_tok_per_s"],
                         "kv_peak_bytes": s["kv_peak_bytes"],
                         "wall_s": round(wall, 4)}
            emit(f"serve_backend_{name}_{family}", wall * 1e6,
                 f"{s['decode_tok_per_s']}tok/s")
        if "paged" in backends:
            assert toks["paged"] == toks["slot"], \
                f"{family}: paged backend diverged from slot backend"
        rec["tokens_identical"] = len(set(map(str, toks.values()))) == 1
        out[family] = rec
    return out


def run() -> None:
    """Bench-harness entry (benchmarks.run suite 'serving')."""
    bench_prefill()
    bench_continuous()
    bench_paged()
    bench_prefix_share()
    bench_spec()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes + JSON summary")
    ap.add_argument("--paged", action="store_true",
                    help="paged vs slot-pool admission under one KV budget")
    ap.add_argument("--prefix-share", action="store_true",
                    help="copy-on-write prefix sharing vs unshared paged "
                    "admission under one KV budget")
    ap.add_argument("--backend-smoke", action="store_true",
                    help="both decode backends per supporting family + the "
                    "prefix-share workload (self-asserting; make "
                    "backend-smoke)")
    ap.add_argument("--tiered", action="store_true",
                    help="tiered memory smoke: shard-resident weight "
                    "packing beats whole-model promotion, and host-DRAM "
                    "KV demotion admits more live requests under one "
                    "budget (self-asserting; make tier-smoke)")
    ap.add_argument("--spec", action="store_true",
                    help="speculative decode vs plain decode on both inner "
                    "backends (self-asserting: token-identical, accept "
                    "rate, target steps < generated tokens; make "
                    "spec-smoke)")
    ap.add_argument("--draft-model", default=None,
                    help="draft arch for --spec (default: self-draft)")
    ap.add_argument("--draft-k", type=int, default=4)
    ap.add_argument("--arch", default="qwen3-0.6b")
    args = ap.parse_args()
    if args.tiered:
        out = {"tiered_weights": bench_tiered_weights(arch=args.arch),
               "tiered_kv": bench_tiered_kv(arch=args.arch)}
        print(json.dumps(out))
    elif args.spec:
        print(json.dumps({"spec": bench_spec(
            arch=args.arch, draft_arch=args.draft_model,
            draft_k=args.draft_k)}))
    elif args.backend_smoke:
        out = {"backends": bench_backends(),
               "prefix_share": bench_prefix_share(arch=args.arch)}
        print(json.dumps(out))
    elif args.prefix_share:
        print(json.dumps({"prefix_share": bench_prefix_share(
            arch=args.arch)}))
    elif args.paged:
        print(json.dumps({"paged": bench_paged(arch=args.arch)}))
    elif args.smoke:
        out = {"prefill": bench_prefill(arch=args.arch),
               "continuous": bench_continuous(arch=args.arch)}
        print(json.dumps(out))
    else:
        bench_prefill(arch=args.arch, batch=8, plen=128, max_seq=160)
        bench_continuous(arch=args.arch, n_requests=16, capacity=8)


if __name__ == "__main__":
    main()
