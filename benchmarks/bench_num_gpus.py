"""Paper Fig 9B: speedup vs number of devices (4 models fixed).

Expected: near-linear while #devices < #models, flattening once Hydra runs
out of schedulable models (degree of parallelism inherited from task
parallelism)."""

from __future__ import annotations

from benchmarks.common import (baseline_reports, bert_grid_tasks, emit,
                               run_hydra)


def run():
    base_makespan = None
    for n_dev in [1, 2, 4, 8]:
        tasks = bert_grid_tasks(n_models=4, steps=2)
        orch, report = run_hydra(tasks, n_devices=n_dev, budget=6 * 10**6)
        if base_makespan is None:
            base_makespan = report.makespan
        emit(f"fig9b_gpus{n_dev}", report.makespan * 1e6,
             f"speedup_vs_1dev={base_makespan / report.makespan:.2f};"
             f"util={report.avg_utilization:.2f}")
