"""Paper Fig 8: end-to-end 12-model workload — Hydra vs model parallelism,
pipeline parallelism, and task parallelism, with GPU utilization.

Real training through the SHARP executor at smoke scale; baselines replay
the same measured per-shard unit runtimes under their schedules."""

from __future__ import annotations

from benchmarks.common import (baseline_reports, bert_grid_tasks, emit,
                               run_hydra)

N_DEVICES = 8
BUDGET = 4500 * 10**3   # < one whole model+opt: task parallelism OOMs (paper §2.2)


def run():
    tasks = bert_grid_tasks(n_models=12, steps=2)
    orch, report = run_hydra(tasks, n_devices=N_DEVICES, budget=BUDGET)
    base = baseline_reports(orch, tasks, N_DEVICES, BUDGET)
    mp = base["model_parallel"]

    emit("fig8_hydra", report.makespan * 1e6,
         f"speedup_vs_mp={mp.makespan / report.makespan:.2f};"
         f"util={report.avg_utilization:.2f}")
    emit("fig8_model_parallel", mp.makespan * 1e6,
         f"speedup_vs_mp=1.00;util={mp.avg_utilization:.2f}")
    pipe = base["pipeline"]
    emit("fig8_pipeline", pipe.makespan * 1e6,
         f"speedup_vs_mp={mp.makespan / pipe.makespan:.2f};"
         f"util={pipe.avg_utilization:.2f}")
    tp = base["task_parallel"]
    if tp is None:
        emit("fig8_task_parallel", 0.0,
             "OOM=model_exceeds_single_device (paper §2.2: cannot run)")
    else:
        emit("fig8_task_parallel", tp.makespan * 1e6,
             f"speedup_vs_mp={mp.makespan / tp.makespan:.2f};"
             f"util={tp.avg_utilization:.2f}")
