"""Paper Fig 9A: speedup vs number of models (8 devices fixed).

Expected shape: ~linear speedup over model parallelism until #models
saturates #devices, then flat (SHARP inherits task parallelism's limit)."""

from __future__ import annotations

from benchmarks.common import (baseline_reports, bert_grid_tasks, emit,
                               run_hydra)


def run():
    for n_models in [2, 4, 8, 12]:
        tasks = bert_grid_tasks(n_models=n_models, steps=2)
        orch, report = run_hydra(tasks, n_devices=8, budget=6 * 10**6)
        mp = baseline_reports(orch, tasks, 8, 6 * 10**6)["model_parallel"]
        emit(f"fig9a_models{n_models}", report.makespan * 1e6,
             f"speedup_vs_mp={mp.makespan / report.makespan:.2f};"
             f"util={report.avg_utilization:.2f}")
