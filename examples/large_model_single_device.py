"""The paper's scalability claim (§4.2): "even a trillion-parameter model can
now be trained on a single GPU out of the box, given sufficient DRAM."

We demonstrate at container scale: a model whose parameters + optimizer
state are ~8x the device budget trains on ONE virtual device purely through
model spilling — the partitioner cuts it into shards that fit, the memory
manager stages them through the device, and training proceeds normally.

    PYTHONPATH=src python examples/large_model_single_device.py
"""

import jax

from repro.configs import get_config
from repro.core import HydraConfig, ModelOrchestrator, ModelTask
from repro.core.partitioner import tree_bytes
from repro.data import DataConfig, SyntheticTokens


def main():
    # an 8-layer model, budget sized so only ~1/4 of it fits at once
    cfg = get_config("qwen3-0.6b", smoke=True).replace(n_layers=8)
    budget = 14 * 10**6

    data = SyntheticTokens(DataConfig(batch_size=2, seq_len=64,
                                      vocab_size=cfg.vocab_size, seed=0))
    task = ModelTask(cfg, data, lr=1e-3, epochs=1, steps_per_epoch=4,
                     batch=2, seq=64)
    orch = ModelOrchestrator([task], HydraConfig(
        n_devices=1, device_budget_bytes=budget))

    m = orch.models[0]
    model_bytes = tree_bytes(m.store.params) * 4   # params+grads+adam
    print(f"model + optimizer state : {model_bytes / 1e6:7.1f} MB")
    print(f"device budget           : {budget / 1e6:7.1f} MB")
    print(f"shards                  : {len(m.partition.shards)}")
    for s in m.partition.shards:
        segs = m.plan.segments[s.seg_lo:s.seg_hi]
        print(f"  shard {s.index}: segments [{segs[0].name} .. "
              f"{segs[-1].name}]  {s.param_bytes / 1e6:6.1f} MB")

    report = orch.train_models()
    print(f"\nlosses: {[round(l, 4) for l in report.losses[0]]}")
    dev = report.transfer[0]
    print(f"promoted {dev.promoted_bytes / 1e6:.0f} MB / "
          f"demoted {dev.demoted_bytes / 1e6:.0f} MB through the device")
    assert model_bytes > budget, "model really is larger than the device"
    print("OK: larger-than-device model trained on one device via spilling")

    # paper §6: the same machinery serves larger-than-device INFERENCE
    from repro.core.orchestrator import SpilledInference
    infer = SpilledInference(cfg, orch.model_params(0),
                             device_budget_bytes=budget // 3,
                             batch=2, seq=64)
    batch = next(iter(SyntheticTokens(DataConfig(
        batch_size=2, seq_len=64, vocab_size=cfg.vocab_size, seed=7))))
    logits = infer(batch)
    print(f"spilled inference: {infer.n_shards} shards, "
          f"logits {tuple(logits.shape)}, "
          f"loss {float(infer.loss(batch)):.4f}")


if __name__ == "__main__":
    main()
