"""The paper's scalability claim (§4.2): "even a trillion-parameter model can
now be trained on a single GPU out of the box, given sufficient DRAM."

We demonstrate at container scale through one ``hydra.Session``: a model
whose parameters + optimizer state are ~8x the device budget trains on ONE
virtual device purely through model spilling — the planner cuts it into
shards that fit, the memory manager stages them through the device, and
training proceeds normally.  The same session machinery then evaluates the
trained model forward-only under an even tighter budget (paper §6: spilled
large-model inference) via an ``EvalJob``.

    PYTHONPATH=src python examples/large_model_single_device.py
"""

import hydra

from repro.configs import get_config
from repro.core.partitioner import tree_bytes
from repro.data import DataConfig, SyntheticTokens


def loader(cfg, seed):
    return SyntheticTokens(DataConfig(batch_size=2, seq_len=64,
                                      vocab_size=cfg.vocab_size, seed=seed))


def main():
    # an 8-layer model, budget sized so only ~1/4 of it fits at once
    cfg = get_config("qwen3-0.6b", smoke=True).replace(n_layers=8)
    budget = 14 * 10**6

    session = hydra.Session(hydra.HydraConfig(
        n_devices=1, device_budget_bytes=budget))
    session.submit(hydra.TrainJob(cfg, loader(cfg, 0), lr=1e-3, epochs=1,
                                  steps_per_epoch=4, batch=2, seq=64))
    plan = session.plan()

    m = session.train_execs[0]
    model_bytes = tree_bytes(m.store.params) * 4   # params+grads+adam
    print(f"model + optimizer state : {model_bytes / 1e6:7.1f} MB")
    print(f"device budget           : {budget / 1e6:7.1f} MB")
    print(f"shards                  : {len(m.partition.shards)}")
    for s in m.partition.shards:
        segs = m.plan.segments[s.seg_lo:s.seg_hi]
        print(f"  shard {s.index}: segments [{segs[0].name} .. "
              f"{segs[-1].name}]  {s.param_bytes / 1e6:6.1f} MB")

    report = session.run(plan)
    train = report.train
    print(f"\nlosses: {[round(l, 4) for l in train.losses[0]]}")
    dev = train.transfer[0]
    print(f"promoted {dev.promoted_bytes / 1e6:.0f} MB / "
          f"demoted {dev.demoted_bytes / 1e6:.0f} MB through the device")
    assert model_bytes > budget, "model really is larger than the device"
    print("OK: larger-than-device model trained on one device via spilling")

    # paper §6: the same machinery serves larger-than-device INFERENCE —
    # an EvalJob under a 3x tighter budget, forward-only through the
    # shard queue, on the weights the session just trained
    evaler = hydra.Session(hydra.HydraConfig(
        n_devices=1, device_budget_bytes=budget // 3))
    jid = evaler.submit(hydra.EvalJob(cfg, loader(cfg, 7), n_batches=1,
                                      params=m.store.model_params(),
                                      batch=2, seq=64))
    rec = evaler.run().evals[jid]
    print(f"spilled eval: {rec['n_shards']} shards, "
          f"{rec['bytes_moved'] / 1e6:.0f} MB moved, "
          f"loss {rec['mean_loss']:.4f}, ppl {rec['perplexity']:.1f}")


if __name__ == "__main__":
    main()
