"""Quickstart — the paper's Fig-4 API through the unified session, in
20 lines.

    PYTHONPATH=src python examples/quickstart.py

Trains two BERT*-class models concurrently with SHARP on 2 virtual devices
(plan first, then execute the same Plan), then verifies the losses match
plain sequential training.
"""

import hydra

from repro.configs import get_config
from repro.core import ModelTask, train_sequential_reference
from repro.data import DataConfig, SyntheticTokens


def loader(cfg, seed):
    return SyntheticTokens(DataConfig(batch_size=2, seq_len=64,
                                      vocab_size=cfg.vocab_size, seed=seed))


def main():
    cfg = get_config("bert-large-1b", smoke=True)

    session = hydra.Session(hydra.HydraConfig(
        n_devices=2, device_budget_bytes=6 * 10**6))
    session.submit(hydra.TrainJob(cfg, loader(cfg, 0), lr=1e-3, epochs=1,
                                  steps_per_epoch=3, batch=2, seq=64))
    session.submit(hydra.TrainJob(cfg, loader(cfg, 1), lr=1e-4, epochs=1,
                                  steps_per_epoch=3, batch=2, seq=64))

    plan = session.plan()        # partitions + spill placement + estimate
    for jid, rec in plan.summary()["jobs"].items():
        print(f"{jid}: {rec['n_shards']} shards, host {rec['host_mb']} MB")

    report = session.run(plan)   # the dry-run's Plan IS the executed one
    train = report.train
    print(f"makespan          {train.makespan * 1e3:.1f} ms (virtual)")
    print(f"avg utilization   {train.avg_utilization:.0%}")
    for mid, losses in train.losses.items():
        print(f"model {mid} losses    {[round(l, 4) for l in losses]}")

    # Hydra's desideratum: no effect on accuracy
    _, ref = train_sequential_reference(
        ModelTask(cfg, loader(cfg, 0), lr=1e-3, epochs=1,
                  steps_per_epoch=3, batch=2, seq=64))
    print(f"sequential ref    {[round(l, 4) for l in ref]}  (model 0)")


if __name__ == "__main__":
    main()
