"""Quickstart — the paper's Fig-4 API in 20 lines.

    PYTHONPATH=src python examples/quickstart.py

Trains two BERT*-class models concurrently with SHARP on 2 virtual devices,
then verifies the losses match plain sequential training.
"""

import jax

from repro.configs import get_config
from repro.core import (HydraConfig, ModelOrchestrator, ModelTask,
                        train_sequential_reference)
from repro.data import DataConfig, SyntheticTokens


def loader(cfg, seed):
    return SyntheticTokens(DataConfig(batch_size=2, seq_len=64,
                                      vocab_size=cfg.vocab_size, seed=seed))


def main():
    cfg = get_config("bert-large-1b", smoke=True)

    task_0 = ModelTask(cfg, loader(cfg, 0), lr=1e-3, epochs=1,
                       steps_per_epoch=3, batch=2, seq=64)
    task_1 = ModelTask(cfg, loader(cfg, 1), lr=1e-4, epochs=1,
                       steps_per_epoch=3, batch=2, seq=64)
    orchestra = ModelOrchestrator(
        [task_0, task_1],
        HydraConfig(n_devices=2, device_budget_bytes=6 * 10**6))
    report = orchestra.train_models()

    print(f"makespan          {report.makespan * 1e3:.1f} ms (virtual)")
    print(f"avg utilization   {report.avg_utilization:.0%}")
    for mid, losses in report.losses.items():
        print(f"model {mid} losses    {[round(l, 4) for l in losses]}")

    # Hydra's desideratum: no effect on accuracy
    _, ref = train_sequential_reference(
        ModelTask(cfg, loader(cfg, 0), lr=1e-3, epochs=1,
                  steps_per_epoch=3, batch=2, seq=64))
    print(f"sequential ref    {[round(l, 4) for l in ref]}  (model 0)")


if __name__ == "__main__":
    main()
