"""Batched serving example: batched prefill into the decode cache, then a
greedy decode loop — across three architecture families (dense GQA, MoE,
and a recurrent xLSTM whose state is O(1) in context length).

``make_prefill_into_cache`` consumes the whole prompt in one jitted call on
attention families and falls back to a scanned per-token loop on recurrent
ones; the callers look identical.  For the full continuous-batching engine
(request queue, KV-budget admission, multi-model LRTF routing) see
``repro.serving`` / docs/serving.md.

    PYTHONPATH=src python examples/serve_batched.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import api
from repro.training import make_decode_step, make_prefill_into_cache


def serve_one(arch: str, batch=2, prompt_len=16, gen=8):
    cfg = get_config(arch, smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    state = api.init_decode_state(cfg, batch, prompt_len + gen + 4)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (batch, prompt_len),
                                0, cfg.vocab_size, jnp.int32)

    prefill = jax.jit(make_prefill_into_cache(cfg))
    t0 = time.perf_counter()
    last_logits, state = prefill(params, state, prompt)
    last_logits = jax.block_until_ready(last_logits)
    prefill_s = time.perf_counter() - t0

    decode = jax.jit(make_decode_step(cfg))
    tok = jnp.argmax(last_logits, axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(gen - 1):
        tok, state = decode(params, state, tok)
        out.append(tok)
    jax.block_until_ready(tok)
    decode_s = time.perf_counter() - t0
    gen_toks = jnp.concatenate(out, axis=1)
    mode = "batched" if api.is_attention_family(cfg) else "scanned"
    print(f"{arch:18s} prefill[{mode:7s}] {prefill_s * 1e3:7.1f} ms   "
          f"decode {batch * (gen - 1) / max(decode_s, 1e-9):8.1f} tok/s   "
          f"sample {gen_toks[0, :6].tolist()}")


def main():
    for arch in ("qwen3-0.6b", "mixtral-8x22b", "xlstm-350m"):
        serve_one(arch)


if __name__ == "__main__":
    main()
