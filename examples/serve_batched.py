"""Batched serving example: prefill a batch of prompts, then decode with the
KV cache / recurrent state — across three architecture families (dense GQA,
MoE, and a recurrent xLSTM whose state is O(1) in context length).

    PYTHONPATH=src python examples/serve_batched.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import api
from repro.training import make_decode_step


def serve_one(arch: str, batch=2, prompt_len=16, gen=8):
    cfg = get_config(arch, smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    state = api.init_decode_state(cfg, batch, prompt_len + gen + 4)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (batch, prompt_len),
                                0, cfg.vocab_size, jnp.int32)

    step = jax.jit(lambda p, s, t: api.decode_step(cfg, p, s, t))
    logits = None
    t0 = time.perf_counter()
    for i in range(prompt_len):                       # prefill via decode
        logits, state = step(params, state, prompt[:, i:i + 1])
    prefill_s = time.perf_counter() - t0

    decode = jax.jit(make_decode_step(cfg))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(gen - 1):
        tok, state = decode(params, state, tok)
        out.append(tok)
    jax.block_until_ready(tok)
    decode_s = time.perf_counter() - t0
    gen_toks = jnp.concatenate(out, axis=1)
    print(f"{arch:18s} prefill {prefill_s * 1e3:7.1f} ms   "
          f"decode {batch * (gen - 1) / max(decode_s, 1e-9):8.1f} tok/s   "
          f"sample {gen_toks[0, :6].tolist()}")


def main():
    for arch in ("qwen3-0.6b", "mixtral-8x22b", "xlstm-350m"):
        serve_one(arch)


if __name__ == "__main__":
    main()
