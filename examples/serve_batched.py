"""Batched serving example through one ``hydra.Session``: three
architecture families (dense GQA, MoE, and a recurrent xLSTM whose state is
O(1) in context length) served side by side, the session's LRTF policy
picking which model's engine ticks next.

The dense model admits with power-of-two length buckets (mixed prompt
lengths share one padded prefill trace); the recurrent model keeps
exact-length groups — its state cannot be rewound past a pad tail — and so
does the MoE model, whose capacity-bounded routing would let pad tokens
displace real tokens' expert routes.  One model starts ``cold``: its
params live spilled in the session's host store until the first request
promotes them (SHARP-for-inference).

    PYTHONPATH=src python examples/serve_batched.py
"""

import jax
import jax.numpy as jnp

import hydra

from repro.configs import get_config

ARCHS = ("qwen3-0.6b", "mixtral-8x22b", "xlstm-350m")
GEN = 8


def prompts_for(cfg, n, seed):
    # deliberately mixed lengths: bucketing groups them into one prefill
    lens = [11 + 2 * i for i in range(n)]
    return [jax.random.randint(jax.random.PRNGKey(seed + i), (L,), 0,
                               cfg.vocab_size, jnp.int32) for i, L in
            enumerate(lens)]


def main():
    session = hydra.Session(hydra.HydraConfig(scheduler="lrtf"))
    for i, arch in enumerate(ARCHS):
        cfg = get_config(arch, smoke=True)
        session.submit(hydra.ServeJob(
            cfg, seed=i, name=arch, capacity=4, max_seq=64,
            bucket_sizes="pow2",            # no-op on moe/recurrent families
            cold=(arch == "mixtral-8x22b")))

    for i, arch in enumerate(ARCHS):
        cfg = get_config(arch, smoke=True)
        for p in prompts_for(cfg, 3, seed=10 * i):
            session.submit_request(arch, p, GEN)

    report = session.run()
    for jid, rec in sorted(report.serve.items()):
        cold = (f"  (cold: promoted {rec['promote_bytes'] / 1e6:.0f} MB "
                f"in {rec['promote_s'] * 1e3:.0f} ms)"
                if rec.get("cold") else "")
        print(f"{rec['model']:18s} {rec['n_completed']} done   "
              f"prefill_calls={rec['prefill_calls']} "
              f"buckets={rec['bucket_sizes']}   "
              f"decode {rec['decode_tok_per_s'] or 0:8.1f} tok/s{cold}")
    print(f"schedule: {report.serve_trace[:12]} ...")


if __name__ == "__main__":
    main()
