"""End-to-end model-selection driver (the paper's core workload):
a hyper-parameter grid trained concurrently under SHARP through one
``hydra.Session``, with the schedule compared against model/pipeline/task
parallelism — a miniature of paper Fig 8.

    PYTHONPATH=src python examples/model_selection.py
"""

import hydra

from repro.configs import get_config
from repro.core import baselines as bl
from repro.data import DataConfig, SyntheticTokens

N_DEVICES = 4
BUDGET = 4500 * 10**3


def main():
    cfg = get_config("bert-large-1b", smoke=True)
    grid = [(lr, bs) for lr in (1e-3, 1e-4, 1e-5) for bs in (2, 4)]

    session = hydra.Session(hydra.HydraConfig(
        n_devices=N_DEVICES, device_budget_bytes=BUDGET))
    for i, (lr, bs) in enumerate(grid):
        data = SyntheticTokens(DataConfig(batch_size=bs, seq_len=64,
                                          vocab_size=cfg.vocab_size, seed=i))
        session.submit(hydra.TrainJob(cfg, data, lr=lr, epochs=1,
                                      steps_per_epoch=2, seed=i,
                                      batch=bs, seq=64))

    report = session.run(session.plan())
    train = report.train

    steps = [j.epochs * j.steps_per_epoch
             for j in session.jobs().values()
             if isinstance(j, hydra.TrainJob)]
    models = session.train_execs
    mp = bl.model_parallel(models, N_DEVICES, steps)
    pipe = bl.pipeline(models, N_DEVICES, steps)

    print(f"{'paradigm':18s} {'makespan':>12s} {'util':>6s}")
    print(f"{'hydra (SHARP)':18s} {train.makespan:12.4f} "
          f"{train.avg_utilization:6.0%}")
    print(f"{'model parallel':18s} {mp.makespan:12.4f} "
          f"{mp.avg_utilization:6.0%}")
    print(f"{'pipeline':18s} {pipe.makespan:12.4f} "
          f"{pipe.avg_utilization:6.0%}")
    try:
        tp = bl.task_parallel(models, N_DEVICES, steps, BUDGET)
        print(f"{'task parallel':18s} {tp.makespan:12.4f} "
              f"{tp.avg_utilization:6.0%}")
    except MemoryError as e:
        print(f"{'task parallel':18s} {'CRASH (OOM)':>12s}   — {e}")

    best = min(train.losses, key=lambda m: train.losses[m][-1])
    lr, bs = grid[best]
    print(f"\nbest config: model {best} (lr={lr}, batch={bs}) "
          f"final loss {train.losses[best][-1]:.4f}")


if __name__ == "__main__":
    main()
